"""Shared model components: norms, rotary embeddings (incl. M-RoPE), MLPs.

All modules are pure functions: ``init_*(key, ...) -> params`` and
``apply(params, x, ...) -> y``.  Every array is created with an explicit
dtype (the relational core enables jax_enable_x64; model code never relies on
defaults).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "init_rmsnorm", "rmsnorm",
    "init_dense", "init_mlp", "mlp",
    "rope", "apply_rope", "mrope_freqs",
    "softcap",
]


# -- RMSNorm -----------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.zeros((d,), dtype=dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    # gemma-style (1 + w) parameterization; init scale=0 → identity
    return (xf * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


# -- Linear / MLP ----------------------------------------------------------

def init_dense(key, d_in: int, d_out: int, dtype=jnp.float32, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), dtype=jnp.float32).astype(dtype) * scale


def init_mlp(key, d_model: int, d_ff: int, mlp_type: str, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if mlp_type == "gated_silu" or mlp_type == "gated_gelu":
        return {
            "wg": init_dense(ks[0], d_model, d_ff, dtype),
            "wi": init_dense(ks[1], d_model, d_ff, dtype),
            "wo": init_dense(ks[2], d_ff, d_model, dtype),
        }
    if mlp_type == "gelu":
        return {
            "wi": init_dense(ks[1], d_model, d_ff, dtype),
            "wo": init_dense(ks[2], d_ff, d_model, dtype),
        }
    raise ValueError(mlp_type)


def mlp(params, x, mlp_type: str):
    if mlp_type == "gated_silu":
        h = jax.nn.silu(x @ params["wg"]) * (x @ params["wi"])
    elif mlp_type == "gated_gelu":
        h = jax.nn.gelu(x @ params["wg"], approximate=True) * (x @ params["wi"])
    elif mlp_type == "gelu":
        h = jax.nn.gelu(x @ params["wi"], approximate=True)
    else:
        raise ValueError(mlp_type)
    return h @ params["wo"]


# -- Rotary position embeddings ----------------------------------------------

def rope(positions: jnp.ndarray, head_dim: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions [..., S] -> (sin, cos) each [..., S, head_dim//2], f32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles), jnp.cos(angles)


def mrope_freqs(positions: jnp.ndarray, head_dim: int, theta: float,
                sections: Tuple[int, ...]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Multimodal RoPE (Qwen2-VL): 3 position streams (t, h, w) own disjoint
    frequency sections.  positions: [3, B, S]; sections sum to head_dim//2."""
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles_all = positions.astype(jnp.float32)[..., None] * freqs  # [3,B,S,half]
    parts = []
    start = 0
    for i, sec in enumerate(sections):
        parts.append(angles_all[i, ..., start:start + sec])
        start += sec
    angles = jnp.concatenate(parts, axis=-1)  # [B,S,half]
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jnp.ndarray, sin: jnp.ndarray, cos: jnp.ndarray) -> jnp.ndarray:
    """x: [B, S, H, D]; sin/cos: [B, S, D//2] (broadcast over heads)."""
    dt = x.dtype
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    s = sin[..., None, :]  # head axis
    c = cos[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(dt)


# -- misc -----------------------------------------------------------------

def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (jnp.tanh(x.astype(jnp.float32) / cap) * cap).astype(x.dtype)
