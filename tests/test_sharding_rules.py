"""Sharding rules: every full-config parameter spec must divide the mesh.

These tests catch config/sharding regressions WITHOUT compiling: they build
abstract params for all 10 production architectures and check each
PartitionSpec'd dimension divides the (16, 16) axes.
"""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.distributed.sharding import param_specs
from repro.launch.specs import abstract_params, sharded_config

MESH_SIZES = {"data": 16, "model": 16, "pod": 2}


def _axis_size(entry):
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        return int(np.prod([MESH_SIZES[a] for a in entry]))
    return MESH_SIZES[entry]


@pytest.mark.parametrize("arch", list_archs())
def test_param_specs_divisible(arch):
    cfg = sharded_config(get_config(arch))
    params = abstract_params(cfg)
    specs = param_specs(params, cfg)
    flat_p = jax.tree_util.tree_leaves_with_path(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for (path, leaf), spec in zip(flat_p, flat_s):
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
        for dim, entry in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if entry is None:
                continue
            size = _axis_size(entry)
            assert dim % size == 0, (
                f"{arch}: {jax.tree_util.keystr(path)} dim {dim} not divisible "
                f"by {entry}={size} (shape {leaf.shape}, spec {spec})")


@pytest.mark.parametrize("arch", list_archs())
def test_large_params_are_sharded(arch):
    """Nothing bigger than 64 MB (bf16) may be fully replicated."""
    cfg = sharded_config(get_config(arch))
    params = abstract_params(cfg)
    specs = param_specs(params, cfg)
    flat_p = jax.tree_util.tree_leaves_with_path(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for (path, leaf), spec in zip(flat_p, flat_s):
        nbytes = int(np.prod(leaf.shape)) * 2
        if nbytes > 64 * 2**20:
            assert any(e is not None for e in spec), (
                f"{arch}: {jax.tree_util.keystr(path)} "
                f"({nbytes / 2**20:.0f} MB) is replicated")


def test_vocab_padding():
    cfg = sharded_config(get_config("mamba2-370m"))
    assert cfg.padded_vocab % 256 == 0
    assert cfg.padded_vocab >= cfg.vocab_size
    # unpadded configs unchanged
    assert get_config("mamba2-370m").padded_vocab == 50_280
