"""Core of the reproduction: tensor-based execution paths for high-dimensional
relational operations, with execution-time path selection (the paper's
contribution), plus the faithful linear (spilling) baseline it is measured
against.

Layered, front to back:

  * **Front-end** — :class:`Session` / :class:`Query` (fluent builder),
    the typed expression language (:func:`col`, :func:`lit`,
    :class:`Expr`), and the logical IR (``LScan``/``LFilter``/``LProject``/
    ``LJoin``/``LSort``/``LAggregate``/``LGroupBy``) with
    :func:`from_physical` as the legacy lowering shim.
  * **Planner** — :func:`plan_program` rewrites (filter pushdown, projection
    pruning, multi-key packing) and splits multi-join plans into chained
    ``Join→[Filter]→[Sort]→[Aggregate]`` fragments.
  * **Execution** — :class:`Executor` over physical nodes
    (:class:`Scan`…\\ :class:`Project`), the fused device-resident pipeline
    (:mod:`~repro.core.fused`), per-operator tensor/linear engines, and the
    single-materialization :class:`DeviceRelation` layer.
  * **Decision layer** — :class:`CostModel` (fragment-level regime-shift
    costing), :class:`PathSelector` (execution-time path choice, with a
    per-decision ``work_mem`` override carrying the governor's pressure
    signal), and the :class:`RuntimeProfile` feedback loop.
  * **Residency** — :mod:`~repro.core.table_cache`: device base-table column
    cache and key-cardinality sketches, both content-token keyed and safe
    to share across concurrent sessions.
  * **Serving layer** — :class:`ResourceBroker` (typed :class:`MemoryLease`
    / :class:`DeviceLease` acquisition over every resource, live queue
    depth + EWMA wait tracking, and the :meth:`~ResourceBroker.price`
    quotes that make ``auto`` queue-aware), :class:`MemoryGovernor` (ONE
    memory budget for all concurrent linear operators: full grants,
    policy-driven degradation — floor or proportional-share — admission
    control, a never-over-budget invariant) and :class:`QueryServer`
    (closed-loop concurrent driver over one shared Session, reporting
    P50/P99, spill volume, grant and broker statistics per run — the
    fig11/fig12 reproductions of the paper's tail-latency claim).

See ``docs/ARCHITECTURE.md`` for the full layer map, ``docs/query-api.md``
for the front-end (including the ``explain()`` stage-chain notation), and
``docs/costing.md`` for the decision layer.
"""
from .cost_model import CostConstants, CostModel, FragmentEstimate
from .aggregate import (group_aggregate_device, group_aggregate_linear,
                        group_aggregate_tensor)
from .device_relation import DeviceColumn, DeviceRelation
from .executor import (PHYSICAL_NODES, Aggregate, Executor, Filter, GroupBy,
                       Join, Project, QueryResult, Scan, Sort)
from .expr import Expr, col, lit
from .faults import (DeadlineExceeded, DeviceDispatchError, FaultInjector,
                     GrantTimeout, PreemptedError, QueryRejected, RetryPolicy,
                     SimulatedCrash, SpillCorruptionError, SpillIOError,
                     TransientError)
from .fused import (FusedSpec, match_fragment, pipeline_cache_clear,
                    pipeline_cache_info, run_fused)
from .linear_engine import HashTable, hash_join_linear, sort_linear, table_bytes_estimate
from .logical import (LAggregate, LFilter, LGroupBy, LJoin, LProject, LScan,
                      LSort, from_physical, schema)
from .memory_governor import (BrokerInvariantViolation, FloorGrantPolicy,
                              GovernorStats, GrantPolicy, MemoryGovernor,
                              MemoryGrant, MemoryHold,
                              ProportionalShareGrantPolicy, TieredGrant)
from .metrics import BLOCK_BYTES, LatencyStats, OpMetrics, SpillAccount, latency_stats
from .path_selector import Decision, PathSelector
from .planner import Program, plan_program, prune_columns, push_filters
from .relation import Relation, column_token
from .resource_broker import (BrokerStats, DeviceLease, DeviceQueue,
                              MemoryLease, PreemptToken, PressureQuote,
                              Reservation, ResourceBroker, ResourceRequest,
                              default_broker)
from .runtime_profile import DEFAULT_PROFILE, RuntimeProfile, size_bucket
from .server import (FailedQuery, QueryServer, ServeReport, ServedQuery,
                     ShedQuery)
from .session import Query, Session
from .slo import ArrivalProcess, TenantClass
from .spill import SpillManager
from .tier import (TierConfig, TierLedger, TierManager, TierStats,
                   decode_column, encode_column)
from .table_cache import (KeyStats, get_device_columns, key_stats,
                          pending_upload_bytes, table_cache_clear,
                          table_cache_info)
from .tensor_engine import (
    aligned_join_indices,
    capacity_bucket,
    join_capacity,
    tensor_join,
    tensor_join_aggregate,
    tensor_join_device,
    tensor_sort,
    tensor_sort_device,
)

__all__ = [
    "Aggregate", "ArrivalProcess", "BLOCK_BYTES", "BrokerInvariantViolation",
    "BrokerStats", "CostConstants", "CostModel",
    "DEFAULT_PROFILE", "DeadlineExceeded", "Decision", "DeviceColumn",
    "DeviceDispatchError", "DeviceLease",
    "DeviceQueue", "DeviceRelation",
    "Executor", "Expr", "FailedQuery", "FaultInjector", "Filter",
    "FloorGrantPolicy", "FragmentEstimate",
    "FusedSpec", "GovernorStats", "GrantPolicy", "GrantTimeout", "GroupBy",
    "HashTable", "Join", "KeyStats", "LAggregate", "LFilter", "LGroupBy",
    "LJoin", "LProject", "LScan", "LSort", "LatencyStats",
    "MemoryGovernor", "MemoryGrant", "MemoryHold", "MemoryLease",
    "OpMetrics",
    "PHYSICAL_NODES", "PathSelector", "PreemptToken", "PreemptedError",
    "PressureQuote", "Program", "Project",
    "ProportionalShareGrantPolicy", "Query", "QueryRejected",
    "QueryResult", "QueryServer", "Relation", "Reservation",
    "ResourceBroker",
    "ResourceRequest", "RetryPolicy",
    "RuntimeProfile", "Scan", "ServeReport", "ServedQuery", "Session",
    "ShedQuery", "SimulatedCrash",
    "Sort", "SpillAccount", "SpillCorruptionError", "SpillIOError",
    "TenantClass", "TierConfig", "TierLedger", "TierManager", "TierStats",
    "TieredGrant", "TransientError",
    "SpillManager", "aligned_join_indices", "capacity_bucket", "col",
    "column_token", "default_broker", "from_physical", "get_device_columns",
    "hash_join_linear", "join_capacity", "key_stats",
    "group_aggregate_device", "group_aggregate_linear", "group_aggregate_tensor",
    "latency_stats", "lit", "match_fragment", "pending_upload_bytes",
    "pipeline_cache_clear", "pipeline_cache_info", "plan_program",
    "decode_column", "encode_column",
    "prune_columns", "push_filters", "run_fused", "schema", "size_bucket",
    "sort_linear", "table_bytes_estimate", "table_cache_clear",
    "table_cache_info", "tensor_join", "tensor_join_aggregate",
    "tensor_join_device", "tensor_sort", "tensor_sort_device",
]
