"""Fluent Session/Query quickstart: the declarative front-end.

Builds a 3-table star schema, runs the same query through the fluent API
(logical IR → rewrite planner → chained fused fragments) and through the
legacy physical dataclass tree, and prints what the planner did: filter
pushdown, projection pruning (H2D bytes), fragment chaining, and the
warm-cache steady state.

    PYTHONPATH=src python examples/session_quickstart.py
"""
import numpy as np

from repro.core import (Aggregate, Executor, Filter, Join, Relation, Scan,
                        Session, Sort, col)


def make_tables(n_orders=200_000, n_users=5_000, n_parts=1_000, seed=0):
    rng = np.random.default_rng(seed)
    orders = Relation({
        "uid": rng.integers(0, n_users, n_orders).astype(np.int64),
        "pid": rng.integers(0, n_parts, n_orders).astype(np.int64),
        "w": rng.integers(-50, 50, n_orders).astype(np.int64),
        # a column no query below ever touches: pruning keeps it on host
        "payload": rng.integers(0, 1 << 40, n_orders).astype(np.int64),
    })
    users = Relation({
        "uid": np.arange(n_users, dtype=np.int64),
        "region": rng.integers(0, 4, n_users).astype(np.int64),
    })
    parts = Relation({
        "pid": np.arange(n_parts, dtype=np.int64),
        "price": rng.integers(1, 9, n_parts).astype(np.int64),
    })
    return orders, users, parts


def main():
    orders, users, parts = make_tables()
    sess = Session(work_mem=1 << 20, policy="auto")
    sess.register("orders", orders)
    sess.register("users", users)
    sess.register("parts", parts)

    q = (sess.table("orders")
         .join(sess.table("users"), on="uid")
         .join(sess.table("parts"), on="pid")
         .filter((col("w") > 0) & (col("b_region") <= 2))
         .sort("uid")
         .aggregate("w", "sum"))

    print("== plan (after pushdown / pruning / fragment chaining) ==")
    print(q.explain())

    res = q.collect()
    print("\n== cold query ==")
    print(f"result        : {res.scalar}")
    print(f"operators     : {[m.op for m in res.metrics]}")
    print(f"host syncs    : {res.total_host_syncs}")
    print(f"H2D bytes     : {res.total_h2d_bytes:,} "
          f"(orders.payload never moves)")

    warm = q.collect()
    print("\n== warm repeat (base tables device-resident) ==")
    print(f"result        : {warm.scalar}")
    print(f"H2D bytes     : {warm.total_h2d_bytes:,} "
          f"(only the stage-1 intermediate)")
    print(f"wall          : {warm.total_wall_s * 1e3:.1f} ms "
          f"vs cold {res.total_wall_s * 1e3:.1f} ms")

    # the same query as a seed-style physical tree, via the lowering shim
    legacy = Aggregate(
        Sort(Filter(Join(Scan(parts),
                         Join(Scan(users), Scan(orders), "uid"), "pid"),
                    lambda r: (r["w"] > 0) & (r["b_region"] <= 2)),
             ["uid"]), "w", "sum")
    shim = sess.execute(legacy)
    direct = Executor(work_mem=1 << 20, policy="linear").execute(legacy)
    print("\n== legacy dataclass tree ==")
    print(f"via lowering shim : {shim.scalar}")
    print(f"direct executor   : {direct.scalar}")
    assert shim.scalar == direct.scalar == res.scalar
    print("all three paths agree bit-for-bit")

    # multi-key joins: logical-only concept, lowered by key packing
    sess.register("events", Relation({
        "uid": orders["uid"][:50_000],
        "pid": orders["pid"][:50_000],
        "cost": np.abs(orders["w"][:50_000]),
    }))
    two = (sess.table("orders")
           .join(sess.table("events"), on=["uid", "pid"])
           .group_by("uid", {"b_cost": "sum"}))
    r2 = two.collect()
    print("\n== multi-key join (packed) ==")
    print(two.explain())
    print(f"groups: {len(r2.relation)}")


if __name__ == "__main__":
    main()
