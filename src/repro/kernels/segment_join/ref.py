"""Pure-jnp oracle for the segment-sum kernel."""
import jax

__all__ = ["segment_sum_ref"]


def segment_sum_ref(seg_ids, values, num_segments: int):
    return jax.ops.segment_sum(values, seg_ids, num_segments=num_segments)
