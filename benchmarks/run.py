"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (common.emit).  Results feed
EXPERIMENTS.md §Repro.  ``--only fig1,headline`` runs a subset; ``--fast``
trims repetition counts for CI-style smoke runs.
"""
from __future__ import annotations

import argparse
import inspect
import json
import os
import pathlib
import sys
import time


def _ensure_device_mesh() -> None:
    """Give the benchmarks the same 8-way forced host mesh the test suite
    gets from tests/conftest.py (fig15 shards over it).  Must run before
    jax initializes, which is why `from .figures import ALL` stays inside
    main(); a user-provided XLA_FLAGS is always respected."""
    if "jax" in sys.modules:
        return  # too late to influence device discovery; leave it alone
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmarks")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--seed", type=int, default=0,
                    help="base seed threaded into workload/arrival/fault "
                         "generation (benchmarks that accept one); recorded "
                         "in the summary so a run can be replayed exactly")
    # default is NOT results/bench_summary.json: that file is the committed
    # p50 baseline benchmarks/compare.py gates against — rewrite it only on
    # purpose, with an explicit --save
    ap.add_argument("--save", default="results/bench_fresh.json")
    args = ap.parse_args()

    _ensure_device_mesh()
    from .figures import ALL
    names = args.only.split(",") if args.only else list(ALL)
    print("name,us_per_call,derived")
    # seed first so every summary records how to replay it (compare.py only
    # reads numeric leaves whose key mentions p50, so this never gates)
    summary = {"run_config": {"seed": args.seed, "fast": bool(args.fast)}}
    for name in names:
        fn = ALL[name]
        t0 = time.time()
        kw = {}
        # inspect.signature sees through functools.wraps/partial wrappers,
        # unlike fn.__code__.co_varnames which only works on plain functions
        try:
            params = inspect.signature(fn).parameters
        except (TypeError, ValueError):
            params = {}
        if args.fast and "reps" in params:
            kw["reps"] = 3
        if "seed" in params:
            kw["seed"] = args.seed
        try:
            summary[name] = fn(**kw)
        except Exception as e:  # keep the harness going; record the failure
            print(f"{name}/ERROR,0,{e!r}", flush=True)
            summary[name] = {"error": repr(e)}
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr,
              flush=True)
    def _keys_to_str(obj):
        if isinstance(obj, dict):
            return {str(k): _keys_to_str(v) for k, v in obj.items()}
        return obj

    out = pathlib.Path(args.save)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(_keys_to_str(summary), indent=1, default=str))
    failed = [name for name, v in summary.items()
              if isinstance(v, dict) and "error" in v]
    if failed:
        # a benchmark that raised (e.g. fig9's warm-cache guard) must turn
        # the CI smoke gate red, not vanish into an ERROR csv row
        print(f"# FAILED: {', '.join(failed)}", file=sys.stderr, flush=True)
        sys.exit(1)


if __name__ == "__main__":
    main()
