"""Temp-file spill manager (PostgreSQL-style work_mem discipline).

Spills are *real* file I/O: the linear execution path writes partition /
sort-run files to a temp directory and reads them back, and every byte is
accounted in a :class:`SpillAccount`.  This is what lets the benchmarks
reproduce the paper's Temp_MB / block counts and the latency impact of the
spill regime, rather than simulating them.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import zlib
from typing import Dict, Optional

import numpy as np

from .faults import FaultInjector, SimulatedCrash, SpillCorruptionError
from .metrics import SpillAccount
from .relation import Relation

__all__ = ["SpillManager", "RunReader", "column_crc32", "CHECKSUM_FILE"]

# Per-column CRC32 manifest written alongside the .npy files (not itself a
# column: readers iterate *.npy only).  Extends the PR 6 crash-consistency
# story to READS: the atomic rename guarantees a complete directory, the
# manifest guarantees the bytes inside it are the bytes that were written.
CHECKSUM_FILE = "checksums.json"


def column_crc32(arr: np.ndarray) -> int:
    """CRC32 over a column's raw little-endian bytes (layout-independent)."""
    return zlib.crc32(np.ascontiguousarray(arr).data) & 0xFFFFFFFF


def verify_column(arr: np.ndarray, name: str, base: str,
                  manifest: Optional[Dict[str, int]]) -> None:
    """Raise :class:`SpillCorruptionError` when ``arr`` fails its recorded
    CRC.  A missing manifest (foreign/legacy spill dir) is accepted."""
    if manifest is None or name not in manifest:
        return
    got = column_crc32(arr)
    if got != manifest[name]:
        raise SpillCorruptionError(
            f"spill column {name!r} at {base!r} failed CRC32 "
            f"(expected {manifest[name]:#010x}, got {got:#010x}) — torn or "
            f"bit-flipped file")


def load_manifest(base: str) -> Optional[Dict[str, int]]:
    path = os.path.join(base, CHECKSUM_FILE)
    try:
        with open(path, "r") as f:
            return {str(k): int(v) for k, v in json.load(f).items()}
    except (OSError, ValueError):
        return None


class SpillManager:
    """Owns a temp directory; writes/reads columnar spill files with accounting.

    ``faults`` wires the spill-write path into a
    :class:`~repro.core.faults.FaultInjector`: every column write first asks
    the injector, which may raise a transient
    :class:`~repro.core.faults.SpillIOError` or a
    :class:`~repro.core.faults.SimulatedCrash` (a mid-write worker death —
    the crash-consistency regression)."""

    def __init__(self, root: Optional[str] = None,
                 faults: Optional[FaultInjector] = None):
        self.dir = tempfile.mkdtemp(prefix="repro_spill_", dir=root)
        self.faults = faults
        self._counter = 0
        # logical bytes per live base path, so delete() can return the exact
        # footprint to the account (true live-occupancy tracking)
        self._sizes: Dict[str, int] = {}

    # -- lifecycle -----------------------------------------------------------
    def cleanup(self) -> None:
        shutil.rmtree(self.dir, ignore_errors=True)
        self._sizes.clear()

    def __enter__(self) -> "SpillManager":
        return self

    def __exit__(self, *exc) -> None:
        self.cleanup()

    def _next_path(self, tag: str) -> str:
        self._counter += 1
        return os.path.join(self.dir, f"{tag}_{self._counter:06d}")

    # -- columnar spill files --------------------------------------------------
    def write_relation(self, rel: Relation, tag: str, account: SpillAccount) -> str:
        """Write a relation as one .npy file per column; returns the base path.

        Crash-consistent finalize: columns land in a ``<base>.tmp`` staging
        directory, every file (and the directory entry) is fsynced, and only
        then is the directory atomically renamed to its final path.  A
        worker killed at ANY instant therefore leaves either a fully-visible
        complete run or an invisible ``.tmp`` orphan — never a final-named
        dir holding a readable-but-truncated relation (which
        ``read_relation``/``RunReader`` would return as silently wrong
        results).  An ordinary write failure (disk full, permission change
        mid-run) removes the staging dir before re-raising so no temp space
        leaks; a :class:`~repro.core.faults.SimulatedCrash` deliberately
        skips that cleanup — a killed process runs no handlers, which is
        exactly what the crash-consistency regression exercises."""
        base = self._next_path(tag)
        tmp = base + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        total = 0
        manifest: Dict[str, int] = {}
        try:
            for name, col in rel.columns.items():
                path = os.path.join(tmp, name + ".npy")
                if self.faults is not None:
                    self.faults.on_spill_column(path)
                np.save(path, col, allow_pickle=False)
                with open(path, "rb") as f:
                    os.fsync(f.fileno())
                manifest[name] = column_crc32(col)
                account.write(col.nbytes)
                total += col.nbytes
            mpath = os.path.join(tmp, CHECKSUM_FILE)
            with open(mpath, "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            dfd = os.open(tmp, os.O_RDONLY)
            try:
                os.fsync(dfd)
            finally:
                os.close(dfd)
            os.rename(tmp, base)  # atomic publish: all columns or nothing
        except SimulatedCrash:
            raise  # a killed worker cleans nothing; .tmp quarantines the wreck
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        account.files_created += len(rel.columns)
        self._sizes[base] = total
        return base

    def read_relation(self, base: str, account: SpillAccount) -> Relation:
        manifest = load_manifest(base)
        cols: Dict[str, np.ndarray] = {}
        for fname in sorted(os.listdir(base)):
            if not fname.endswith(".npy"):
                continue
            path = os.path.join(base, fname)
            if self.faults is not None:
                self.faults.on_spill_read(path)
            arr = np.load(path, allow_pickle=False)
            verify_column(arr, fname[:-4], base, manifest)
            cols[fname[:-4]] = arr
            account.read(arr.nbytes)
        return Relation(cols)

    def open_run_reader(self, base: str, account: SpillAccount) -> "RunReader":
        return RunReader(base, account, faults=self.faults)

    def delete(self, base: str, account: Optional[SpillAccount] = None) -> None:
        """Remove a spill dir and, when an account is given, return its
        logical bytes to the account's live-occupancy counter."""
        freed = self._sizes.pop(base, None)
        if account is not None and freed is not None:
            account.free(freed)
        shutil.rmtree(base, ignore_errors=True)


class RunReader:
    """Chunked reader over a spilled relation (memory-mapped, counts bytes read)."""

    def __init__(self, base: str, account: SpillAccount,
                 faults: Optional[FaultInjector] = None):
        self.account = account
        self.cols: Dict[str, np.ndarray] = {}
        manifest = load_manifest(base)
        for fname in sorted(os.listdir(base)):
            if fname.endswith(".npy"):
                path = os.path.join(base, fname)
                if faults is not None:
                    faults.on_spill_read(path)
                arr = np.load(path, mmap_mode="r", allow_pickle=False)
                # CRC verification at open touches every page once — it is
                # the integrity gate for the whole merge pass; subsequent
                # read_rows() slices stay lazy via the mmap
                verify_column(arr, fname[:-4], base, manifest)
                self.cols[fname[:-4]] = arr
        if not self.cols:
            # a spill dir with no column files (zero-column relation, wrong
            # path, or a cleaned-up partial write) must fail loudly here —
            # `next(iter(...))` would raise bare StopIteration, which a
            # generator-based caller would swallow as silent end-of-stream
            raise ValueError(
                f"spill run at {base!r} contains no column files; cannot "
                f"determine row count")
        self.n = len(next(iter(self.cols.values())))
        self.pos = 0

    @property
    def exhausted(self) -> bool:
        return self.pos >= self.n

    def read_rows(self, nrows: int) -> Relation:
        end = min(self.n, self.pos + nrows)
        out = {}
        for name, col in self.cols.items():
            chunk = np.asarray(col[self.pos : end])  # materialize the slice
            out[name] = chunk
            self.account.read(chunk.nbytes)
        self.pos = end
        return Relation(out)
