"""Gradient compression for cross-pod all-reduce: int8 + error feedback.

At multi-pod scale the gradient all-reduce crosses the slow inter-pod links;
8-bit quantization cuts that traffic 4× (bf16) / 2× (int8 vs bf16).  Error
feedback keeps the quantization bias from accumulating: the residual of each
step is added back before the next quantization (Seide et al. / EF-SGD).

``compress_tree``/``decompress_tree`` are pure and jit-able; the trainer
applies them around the (implicit, GSPMD-inserted) gradient reduction when
``TrainPolicy.compress_grads`` is set — on the dry-run mesh this materializes
as int8 collectives in the HLO, which the roofline parser then prices.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "compress_tree",
           "decompress_tree", "init_error_state", "apply_error_feedback"]


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_tree(grads: Any) -> Any:
    return jax.tree.map(lambda g: quantize_int8(g), grads)


def decompress_tree(cgrads: Any) -> Any:
    return jax.tree.map(lambda qs: dequantize_int8(*qs), cgrads,
                        is_leaf=lambda x: isinstance(x, tuple))


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def apply_error_feedback(grads: Any, error: Any) -> Tuple[Any, Any]:
    """Returns (quantized-and-restored grads, new error residuals)."""
    corrected = jax.tree.map(
        lambda g, e: g.astype(jnp.float32) + e, grads, error)
    restored = jax.tree.map(
        lambda c: dequantize_int8(*quantize_int8(c)), corrected)
    new_error = jax.tree.map(lambda c, r: c - r, corrected, restored)
    return restored, new_error
