"""Quickstart: the paper's mechanism in 60 seconds.

1. Runs the same relational query (join → multi-key sort) through the linear
   path, the tensor path, and execution-time selection, under memory pressure.
2. Trains a tiny MoE LM whose token dispatch uses the same dual-path design.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import Executor, Join, Relation, Scan, Sort


def relational_demo():
    print("=" * 72)
    print("1. Premature dimensional collapse: linear vs tensor execution path")
    print("=" * 72)
    rng = np.random.default_rng(0)
    n = 300_000
    build = Relation({"k": rng.permutation(n).astype(np.int64),
                      "v": rng.integers(0, 1 << 40, n).astype(np.int64)})
    probe = Relation({"k": rng.integers(0, n, n).astype(np.int64),
                      "w": rng.integers(0, 1 << 40, n).astype(np.int64)})
    plan = lambda: Sort(Join(Scan(build), Scan(probe), "k"), ["k", "w"])

    work_mem = 1 << 20  # 1 MB — the paper's pressure regime
    for policy in ("linear", "tensor", "auto"):
        ex = Executor(work_mem=work_mem, policy=policy)
        res = ex.execute(plan())
        ops = ", ".join(f"{m.op}:{m.path}" for m in res.metrics)
        print(f"policy={policy:7s} wall={res.total_wall_s:6.2f}s "
              f"temp={res.total_temp_mb:7.1f}MB  [{ops}]")
        if policy == "auto":
            for d in res.decisions:
                print(f"    selector: {d.path:6s} — {d.reason[:90]}")


def lm_demo():
    print()
    print("=" * 72)
    print("2. The same idea in the LM: MoE dual-path dispatch (tiny train run)")
    print("=" * 72)
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models import cross_entropy_loss, forward, init_model
    from repro.train.optimizer import make_optimizer
    from repro.train.trainer import TrainPolicy, make_train_step

    cfg = get_smoke_config("phi3.5-moe-42b-a6.6b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adamw", lr=1e-2)
    step = jax.jit(make_train_step(
        cfg, opt, TrainPolicy(remat=False, moe_dispatch="auto")))
    opt_state = opt.init(params)
    rng = np.random.default_rng(0)
    for i in range(10):
        toks = rng.integers(0, cfg.vocab_size, (4, 33))
        batch = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
                 "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
        params, opt_state, metrics = step(params, opt_state, batch)
        if i % 3 == 0:
            print(f"  step {i}: loss={float(metrics['loss']):.3f}")
    print("  (dispatch path chosen per step shapes — see repro.models.moe)")


if __name__ == "__main__":
    relational_demo()
    lm_demo()
