"""MoE dual dispatch paths: the paper's linear/tensor dichotomy in the LM.

The central invariant (paper §III.C): path choice never changes semantics —
the sort (linear) and einsum (tensor) dispatches must agree exactly,
including which overflow tokens get dropped.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis; pip install -r requirements.txt")
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.models.moe import (capacity_per_expert, init_moe, moe_forward,
                              select_dispatch_path)


def _cfg(capacity_factor=1.25):
    base = get_smoke_config("phi3.5-moe-42b-a6.6b")
    import dataclasses
    return dataclasses.replace(base, capacity_factor=capacity_factor)


@pytest.mark.parametrize("capacity_factor", [0.5, 1.0, 16.0])
def test_dispatch_paths_agree_exactly(capacity_factor):
    """Same outputs AND same dropped tokens on both paths."""
    cfg = _cfg(capacity_factor)
    key = jax.random.PRNGKey(0)
    params = init_moe(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), jnp.float32)
    y_sort, aux_s = moe_forward(params, x, cfg, dispatch="sort")
    y_einsum, aux_e = moe_forward(params, x, cfg, dispatch="einsum")
    np.testing.assert_allclose(np.asarray(y_sort), np.asarray(y_einsum),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(aux_s), float(aux_e), rtol=1e-6)


def test_capacity_drops_tokens():
    """At tiny capacity the layer output differs from the no-drop output —
    the drop semantics are real, and identical across paths (tested above)."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64), jnp.float32)
    cfg_lo, cfg_hi = _cfg(0.25), _cfg(16.0)
    params = init_moe(key, cfg_hi)
    y_lo, _ = moe_forward(params, x, cfg_lo, dispatch="einsum")
    y_hi, _ = moe_forward(params, x, cfg_hi, dispatch="einsum")
    assert float(jnp.max(jnp.abs(y_lo - y_hi))) > 1e-6


def test_selector_budget_regime():
    """Paper §III.C analogue: the one-hot working set vs the memory budget."""
    d = select_dispatch_path(num_tokens=1 << 20, num_experts=64, capacity=4096,
                             d_model=2048, k=6, budget_bytes=1 << 30)
    assert d.path == "sort" and "exceeds budget" in d.reason
    d = select_dispatch_path(num_tokens=1024, num_experts=8, capacity=256,
                             d_model=64, k=2, budget_bytes=1 << 30)
    assert d.path == "einsum"
    assert select_dispatch_path(8, 2, 8, 4, 1, force="sort").path == "sort"


def test_capacity_alignment():
    c = capacity_per_expert(1000, 8, 2, 1.25)
    assert c % 8 == 0 and c >= 1000 * 2 * 1.25 / 8


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       tokens=st.sampled_from([8, 32, 64]),
       cap=st.sampled_from([0.5, 1.0, 2.0]))
def test_property_paths_agree(seed, tokens, cap):
    cfg = _cfg(cap)
    params = init_moe(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, tokens, cfg.d_model))
    y_s, _ = moe_forward(params, x, cfg, dispatch="sort")
    y_e, _ = moe_forward(params, x, cfg, dispatch="einsum")
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_e),
                               rtol=1e-5, atol=1e-5)
