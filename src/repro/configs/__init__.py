"""Assigned-architecture configuration registry."""
from .base import ArchConfig, get_config, get_smoke_config, list_archs
from .shapes import SHAPES, ShapeSpec, all_cells, applicable

__all__ = [
    "ArchConfig", "SHAPES", "ShapeSpec", "all_cells", "applicable",
    "get_config", "get_smoke_config", "list_archs",
]
