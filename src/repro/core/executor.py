"""Mini cost-based execution engine with *deferred decision points*.

A tiny physical-operator tree (Scan / Filter / Join / Sort / Aggregate) that
models the structure the paper critiques and the fix it proposes:

  * a traditional plan fixes each operator's execution path at *plan time*
    (``policy="linear"`` or ``"tensor"`` pins every operator);
  * the paper's design (``policy="auto"``) leaves join/sort decision points
    *open* and resolves them at execution time via :class:`PathSelector`,
    using the actually-observed input relations.

Tensor-path execution is **device-resident**: once an operator lands on the
tensor path its output stays on device as a :class:`DeviceRelation` (lazy
gather indices + validity mask), downstream tensor operators chain without
any host round trip, and materialization happens exactly once at the query
root (reported as a ``materialize`` entry in the metrics with its host-sync
count).  Recognized ``Join→[Filter]→[Sort]→[Aggregate]`` fragments compile
into a single fused jitted program (see :mod:`repro.core.fused`) that pays
≤ 1 device→host transfer for the whole query.

The executor records per-operator :class:`OpMetrics` so benchmarks can report
latency, Temp_MB, working-set peaks and host-sync counts per path.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, List, Optional, Sequence

import numpy as np

from .device_relation import DeviceRelation
from .faults import (DeviceDispatchError, FaultInjector, PreemptedError,
                     RetryPolicy, TransientError)
from .guards import SwitchPoint
from .linear_engine import hash_join_linear, sort_linear
from .memory_governor import MemoryGovernor
from .metrics import OpMetrics, SpillAccount, Timer
from .path_selector import Decision, PathSelector
from .relation import Relation
from .resource_broker import (PreemptToken, PressureQuote, ResourceBroker,
                              ResourceRequest, default_broker)
from .spill import SpillManager
from .tensor_engine import (tensor_join_device, tensor_sort_device)
from .tier import TierConfig, TierLedger, TierManager

__all__ = ["Scan", "Filter", "Join", "Sort", "Aggregate", "GroupBy",
           "Project", "PHYSICAL_NODES", "Executor", "QueryResult"]


# -- logical plan nodes ------------------------------------------------------

@dataclasses.dataclass
class Scan:
    relation: Relation
    name: str = "scan"


@dataclasses.dataclass
class Filter:
    """Row-wise selection.

    ``predicate`` must be a ROW-WISE (element-wise) expression over the
    relation's columns returning a boolean mask — the relational WHERE
    contract.  On the device-resident paths it may be evaluated over a
    capacity-padded physical row space (masked rows included), so
    whole-column aggregates inside a predicate (e.g. ``r['w'].mean()``)
    are out of contract and would see padding.
    """
    child: object
    predicate: Callable[[Relation], np.ndarray]  # rows mask
    name: str = "filter"


@dataclasses.dataclass
class Join:
    build: object
    probe: object
    key: str
    name: str = "join"


@dataclasses.dataclass
class Sort:
    child: object
    keys: Sequence[str]
    name: str = "sort"


@dataclasses.dataclass
class Aggregate:
    child: object
    column: str
    fn: str = "sum"  # sum | count | min | max
    name: str = "aggregate"


@dataclasses.dataclass
class GroupBy:
    child: object
    key: str
    values: dict  # column -> agg fn
    name: str = "group_by"


@dataclasses.dataclass
class Project:
    """Column subset.  Structural (dict-slice / lazy-column-slice) on both
    regimes — never a data movement; the planner uses it to serve pruned
    output schemas (e.g. dropping a packed join coordinate)."""

    child: object
    columns: Sequence[str]
    name: str = "project"


# the closed set of physical plan nodes; Executor.execute and the planner's
# legacy detection both key off this one tuple (add new nodes HERE)
PHYSICAL_NODES = (Scan, Filter, Join, Sort, Aggregate, GroupBy, Project)

# Process-wide registry of per-operator device shape signatures whose jitted
# programs have (very likely) already compiled — jax's compile cache is
# process-global, so freshness is too.  Exact row counts on purpose: the
# per-op programs compile at exact shapes, and bucketing would classify a
# genuinely-fresh shape as warm (a compile inside an exclusive lease — the
# stall the bypass prevents).  Capped as a backstop: overflow clears the
# registry, costing at most one extra unleased run per shape — jax's own
# compile cache grows one (much larger) entry per shape regardless.
import threading as _threading

_WARM_SIGS: set = set()
_WARM_SIG_LOCK = _threading.Lock()
_WARM_SIGS_CAP = 4096


@dataclasses.dataclass
class QueryResult:
    relation: Optional[Relation]
    scalar: Optional[float]
    metrics: List[OpMetrics]
    decisions: List[Decision]

    @property
    def total_wall_s(self) -> float:
        return sum(m.wall_s for m in self.metrics)

    @property
    def total_temp_mb(self) -> float:
        return sum(m.spill.temp_mb for m in self.metrics)

    @property
    def total_host_syncs(self) -> int:
        return sum(m.host_syncs for m in self.metrics)

    @property
    def total_h2d_bytes(self) -> int:
        """Host→device bytes this query PHYSICALLY transferred (0 when every
        base table was already resident in the device column cache; packed
        codes + dictionaries when compressed layouts are on)."""
        return sum(m.h2d_bytes for m in self.metrics)

    @property
    def total_h2d_bytes_logical(self) -> int:
        """The same transfers priced at logical column width — the upload
        cost without packed device layouts.  physical/logical is the
        query's effective H2D compression ratio."""
        return sum(m.h2d_bytes_logical for m in self.metrics)


class Executor:
    """Walks a plan; resolves deferred join/sort decision points at run time."""

    def __init__(self, work_mem: int, policy: str = "auto",
                 selector: Optional[PathSelector] = None,
                 spill_root: Optional[str] = None,
                 fuse: bool = True,
                 governor: Optional[MemoryGovernor] = None,
                 broker: Optional[ResourceBroker] = None,
                 faults: Optional[FaultInjector] = None,
                 retry: Optional[RetryPolicy] = None,
                 max_shards: int = 1,
                 tiers: Optional[TierConfig] = None,
                 guards: bool = True):
        if policy not in ("auto", "linear", "tensor"):
            raise ValueError(policy)
        if int(max_shards) < 1:
            raise ValueError(f"max_shards must be >= 1, got {max_shards}")
        # Spill-tier hierarchy: when configured, every per-query spill sink
        # becomes a TierManager (T0 compressed host RAM → T1 emulated
        # remote → T2 disk) instead of the flat disk SpillManager, and the
        # selector prices the tiered-linear candidate.  ``tiers=True``
        # enables the default hierarchy.
        if tiers is True:
            tiers = TierConfig()
        self.tiers = tiers
        # Session-lifetime balance ledger: every per-query TierManager
        # absorbs its per-tier byte counters (and any leaked pool bytes)
        # here at cleanup; verify_balanced() is the leak/imbalance gate.
        self.tier_ledger = TierLedger() if tiers is not None else None
        force = None if policy == "auto" else policy
        self.selector = selector or PathSelector(work_mem, force=force,
                                                 tiers=tiers)
        if selector is not None and force is not None:
            self.selector.force = force
        if selector is not None and tiers is not None \
                and getattr(selector, "tiers", None) is None:
            selector.tiers = tiers
        self.work_mem = work_mem
        self.spill_root = spill_root
        self.fuse = fuse
        # Every resource acquisition goes through ONE broker: memory leases
        # for linear operators (when a governor exists), device leases for
        # fused and per-operator tensor dispatch, and the pressure quotes
        # the selector folds into path costs.  A governor without a broker
        # gets a private broker; no governor falls back to the process-wide
        # default broker (device-only — its queue is THE queue for every
        # broker-less session, preserving one-device serialization).
        if broker is None:
            # an auto-built broker SHARES the process-wide device queue:
            # the physical device is one resource however many governed
            # sessions exist, and a private queue here would let two
            # sessions' fused programs time-slice against each other —
            # the tail the queue exists to remove.  Per-server private
            # queues are an explicit choice (QueryServer passes one).
            broker = (ResourceBroker(governor,
                                     device_queue=default_broker().device)
                      if governor is not None else default_broker())
        elif governor is not None and broker.governor is not governor:
            raise ValueError(
                "pass either governor or broker (or a broker built over "
                "that governor); conflicting governors would split the "
                "budget accounting")
        self.broker = broker
        # Shared memory governor (concurrent serving): linear operators
        # acquire a grant before building their linearized intermediate and
        # the GRANT size — not the static work_mem — bounds their memory.
        # None keeps the single-query semantics: a private work_mem.
        self.governor = governor if governor is not None else broker.governor
        # Fault handling: the injector (also reachable through the broker,
        # which owns the device/grant sites) feeds the spill-write site via
        # the per-query SpillManager; the retry policy drives the
        # TransientError backoff loop and the device path-fallback
        # threshold.  Thread-local state because one executor serves many
        # worker threads: a device failing for THIS query must not pin a
        # neighbor's path.
        self.faults = faults if faults is not None else broker.faults
        self.retry = retry if retry is not None else RetryPolicy()
        # Lane fan-out ceiling for fused fragments: 1 (default) keeps every
        # dispatch on the single-device path; N > 1 lets choose_fragment
        # price the partition-parallel sharded program (capped at the mesh's
        # actual device count at decision time) and run_fused fan out over N
        # broker lanes when it wins.
        self.max_shards = int(max_shards)
        # Execution-time guards (mid-query adaptive re-planning): when on,
        # every costed LINEAR join/sort runs under an ExecutionGuard that
        # re-checks the decision at partition boundaries and can abandon a
        # mispriced operator for the tensor path mid-query, reusing its
        # already-spilled partitions.  ``guards=False`` is the static-
        # decision ablation the fig14 robustness map measures against.
        self.guards = bool(guards)
        self._tls = _threading.local()

    # -- memory grants -------------------------------------------------------
    def _effective_work_mem(self, need_bytes: Optional[int] = None) -> int:
        """The work_mem a linear operator would receive *right now*.
        Decision-time pricing goes through :meth:`_quotes` (grant size AND
        expected waits); this remains the plain grant-size peek for
        diagnostics and callers that only need the memory half.

        ``need_bytes`` (the operator's estimated linearized-intermediate
        footprint) makes the probe EXACTLY the request :meth:`_granted`
        would make, so full-or-floor pricing matches the grant the operator
        would actually receive.  Without it the probe is capped at the
        governor's whole budget — a work_mem larger than the pool itself
        would otherwise read as permanent pressure even when idle."""
        if self.governor is None:
            return self.work_mem
        if need_bytes is None:
            req = min(self.work_mem, self.governor.total_bytes)
        else:
            req = min(self.work_mem, max(1, int(need_bytes)))
        return self.governor.would_grant(req)

    def _quotes(self, need_bytes: int, lanes: int = 1):
        """Broker pricing for one deferred decision: ``(mem_quote,
        dev_quote, reservation)``.  The memory quote is probed with EXACTLY
        the request :meth:`_granted` would make (same ``min(work_mem,
        need)`` sizing), so grant pricing and admission-wait pricing
        describe the queue the operator would actually stand in; the device
        quote prices the dispatch queue the tensor path would join.

        Under a governed broker the memory quote arrives as a
        price-and-hold :class:`~repro.core.resource_broker.Reservation`:
        the quoted bytes are committed until the decision converts the hold
        (linear path — pass the reservation to :meth:`_granted`) or cancels
        it (tensor path / any exception; the caller's ``finally`` must
        cancel, and the TTL backstops leaks).  A forced-policy selector
        never reads quotes — skip the lock-acquiring pricing on that hot
        path."""
        if self.selector.force is not None:
            return None, None, None
        rsv = None
        if self.broker.governor is not None:
            req = min(self.work_mem, max(1, int(need_bytes)))
            rsv = self.broker.reserve(ResourceRequest("memory",
                                                      need_bytes=req))
            mem = rsv.quote
        else:
            # ungoverned: a synthetic full-grant quote at the EXECUTOR's
            # work_mem, preserving the pre-broker contract that decisions
            # are priced against the executor's budget even when the
            # selector was constructed with a different one
            mem = PressureQuote("memory", self.work_mem, 0.0, 0, False)
        dev = self.broker.price(ResourceRequest("device",
                                                lanes=max(1, int(lanes))))
        return mem, dev, rsv

    @contextlib.contextmanager
    def _granted(self, need_bytes: int, reservation=None):
        """Grant scope for one linear operator: yields ``(work_mem, lease)``
        where ``work_mem`` is what the operator must live within and
        ``lease`` is None for ungoverned executors.  Requests the smaller
        of the configured work_mem and the operator's estimated
        linearized-intermediate footprint, so small operators under a
        shared budget don't hoard memory they cannot use.  ``reservation``
        redeems a :meth:`_quotes` hold: the decision's quoted bytes convert
        into the grant with zero admission wait (decide-then-lose closed)."""
        if self.broker.governor is None:
            yield self.work_mem, None
            return
        lease = self.broker.memory_lease(
            min(self.work_mem, max(1, int(need_bytes))),
            reservation=reservation)
        try:
            yield lease.size, lease
        finally:
            lease.release()

    # -- preemption ----------------------------------------------------------
    def _preempt_token(self, lease) -> Optional[PreemptToken]:
        """Register a floor-degraded linear operator as preemptible.  A full
        grant runs as fast as it ever will — only the degraded case (the
        spill wall) is worth abandoning for a tensor requeue."""
        if lease is None or not lease.degraded:
            return None
        token = PreemptToken()
        self.broker.register_preemptible(token)
        return token

    def _drop_token(self, token: Optional[PreemptToken]) -> None:
        if token is not None:
            self.broker.unregister_preemptible(token)

    # -- execution-time guards (mid-query re-planning) -----------------------
    def _guard(self, decision: Decision, op: str, rows_in: int, token):
        """Cancel token for one linear operator: the selector's
        ExecutionGuard when guards are on (wrapping the preempt token so
        broker preemption keeps working through it), else the bare token."""
        return self.selector.make_guard(decision, op, rows_in, token=token,
                                        enabled=self.guards)

    @staticmethod
    def _stamp_switch(m: OpMetrics, sp: SwitchPoint, pre_path: str) -> None:
        """Account a mid-query switch on the metrics of the run that
        finished the operator: the abandoned attempt's wall joins wall_s
        (end-to-end honesty) but is held in pre_switch_wall_s under
        pre_switch_path so profile feedback attributes each half to the
        path that actually burned it."""
        m.switched = True
        m.pre_switch_wall_s = sp.elapsed_s
        m.pre_switch_path = pre_path
        m.wall_s += sp.elapsed_s
        m.decision_reason = sp.reason

    def _complete_join_switch(self, sp: SwitchPoint, key: str, mgr,
                              rows_in: int, pre_path: str):
        """Finish a guard-abandoned Grace join on the tensor path WITHOUT
        losing the linear prefix's work.

        ``sp.done`` partitions are already joined and kept as-is;
        ``sp.pending`` pairs are read back from the spill/tier manager
        (byte-accounted on the operator's own SpillAccount, so the tier
        books stay balanced), deleted, concatenated, and joined by ONE
        :func:`~repro.core.tensor_engine.tensor_join_device` gang
        dispatch.  One dispatch instead of per-pair calls is what makes
        the switch profitable at all: the per-pair fixed cost (~dispatch
        + 2 syncs) is on the order of the linear loop's per-pair work,
        so a pairwise takeover would only break even.  The output stays
        device-resident (like the normal tensor walk) whenever there is
        no host prefix to splice in front of it — materializing the
        joined output to host costs more than the join itself.
        Concatenation is safe AND byte-identical to per-pair joins:
        Grace hash-partitions by key, so every build row for a key lives
        in exactly one partition, the concatenated probe preserves
        (partition, within-partition) order, and the join's stable build
        ordering makes each probe row's match list independent of the
        other partitions' rows."""
        from .tensor_engine import tensor_join, tensor_join_device

        spill = sp.spill if sp.spill is not None else SpillAccount()
        results = list(sp.done)
        reused = 0
        h2d = 0
        h2d_log = 0
        live = [(b, p, nb, npr) for b, p, nb, npr in sp.pending
                if b is not None and p is not None and nb and npr]
        sig = ("switch_join", key, sum(x[2] for x in live),
               sum(x[3] for x in live))
        syncs = 0
        with self._device_leased(sig) as lease:
            with Timer() as t:
                for b_path, p_path, nb, npr in sp.pending:
                    if (b_path is None or p_path is None
                            or nb == 0 or npr == 0):
                        for p in (b_path, p_path):
                            if p:
                                mgr.delete(p, spill)
                        continue
                builds, probes = [], []
                for b_path, p_path, nb, npr in live:
                    b_part = mgr.read_relation(b_path, spill)
                    p_part = mgr.read_relation(p_path, spill)
                    reused += b_part.nbytes() + p_part.nbytes()
                    mgr.delete(b_path, spill)
                    mgr.delete(p_path, spill)
                    builds.append(b_part)
                    probes.append(p_part)
                gang = None
                if builds:
                    b_all = builds[0]
                    for b in builds[1:]:
                        b_all = b_all.concat(b)
                    p_all = probes[0]
                    for p in probes[1:]:
                        p_all = p_all.concat(p)
                    dev_b, up_b, log_b = self._to_device(b_all)
                    dev_p, up_p, log_p = self._to_device(p_all)
                    h2d += up_b + up_p
                    h2d_log += log_b + log_p
                    gang, pm = tensor_join_device(dev_b, dev_p, key)
                    syncs += pm.host_syncs
                if not results and gang is not None:
                    # no host prefix: hand the takeover result downstream
                    # device-resident, exactly like the tensor walk would
                    out = gang
                elif gang is None and not results:
                    # all partitions empty: schema-correct empty result
                    b_schema, p_schema = sp.schema_hint
                    empty_b = Relation(
                        {k: v[:0] for k, v in b_schema.items()})
                    empty_p = Relation(
                        {k: v[:0] for k, v in p_schema.items()})
                    out, pm = tensor_join(empty_b, empty_p, key)
                    syncs += pm.host_syncs
                else:
                    if gang is not None:
                        results.append(gang.to_host())
                        syncs += 1
                    out = results[0]
                    for r in results[1:]:
                        out = out.concat(r)
        m = OpMetrics(op="hash_join", path="tensor", rows_in=rows_in,
                      rows_out=len(out), wall_s=t.elapsed, spill=spill,
                      host_syncs=syncs, reused_spill_bytes=reused)
        m.h2d_bytes += h2d
        m.h2d_bytes_logical += h2d_log
        self._stamp_lease(m, lease)
        self._stamp_switch(m, sp, pre_path)
        self.broker.note_switch()
        return out, m

    # -- transient-fault handling --------------------------------------------
    def _forced_linear(self) -> bool:
        return getattr(self._tls, "force_path", None) == "linear"

    def _decide(self, decision: Decision) -> Decision:
        """Apply this thread's device path-fallback to a selector decision:
        after repeated device-dispatch failures the rest of the query runs
        linear whatever the costs say — the selector prices a healthy
        device, and the fault counter is the evidence it is wrong."""
        if decision.path == "tensor" and self._forced_linear():
            return dataclasses.replace(
                decision, path="linear",
                reason="device-fallback: " + decision.reason)
        return decision

    def _note_transient(self, exc: TransientError) -> None:
        """Per-thread failure accounting: repeated device-dispatch failures
        pin the REST of this thread's current query onto the linear path
        (path fallback) — a sick device must degrade service, not abort it."""
        if isinstance(exc, DeviceDispatchError):
            fails = getattr(self._tls, "device_failures", 0) + 1
            self._tls.device_failures = fails
            if fails >= self.retry.device_fallback_after:
                self._tls.force_path = "linear"

    def _reset_fault_state(self) -> None:
        self._tls.force_path = None
        self._tls.device_failures = 0

    @contextlib.contextmanager
    def _device_leased(self, sig: object = None):
        """Device lease scope for one per-operator tensor dispatch.  The
        shared ``"per-op"`` batch bucket lets concurrent per-operator work
        coalesce with itself (its device programs are small and lazy) while
        still queueing, in arrival order, behind exclusive fused dispatches.
        The lease wait is load, not cost: callers stamp it into
        ``OpMetrics.queue_wait_s`` so profile feedback excludes it.

        ``sig`` is the call's shape signature: its FIRST sighting process-
        wide runs without a lease (yields None), because a first call of a
        jitted per-operator program pays XLA compilation — seconds spent
        inside the queue would stall every other query's device phase.
        This mirrors ``run_fused``'s fresh-program bypass; per-op programs
        have no explicit compile cache to ask, so the signature registry is
        the freshness oracle (approximate is fine — a misclassification
        costs one unqueued warm run or one queued compile, never a wrong
        result)."""
        if sig is not None:
            with _WARM_SIG_LOCK:
                fresh = sig not in _WARM_SIGS
            if fresh:
                yield None
                # registered only on normal completion: a run that raised
                # may never have finished compiling, and treating the
                # shape as warm would put the retry's compile INSIDE an
                # exclusive lease — the stall this bypass exists to avoid
                with _WARM_SIG_LOCK:
                    if len(_WARM_SIGS) >= _WARM_SIGS_CAP:
                        _WARM_SIGS.clear()
                    _WARM_SIGS.add(sig)
                return
        lease = self.broker.device_lease(batch_key="per-op")
        try:
            yield lease
        finally:
            lease.release()

    @staticmethod
    def _stamp_grant(m: OpMetrics, grant) -> None:
        if grant is not None:
            m.grant_bytes = grant.size
            m.grant_degraded = grant.degraded
            m.mem_wait_s = grant.wait_s

    @staticmethod
    def _stamp_lease(m: OpMetrics, lease) -> None:
        """Device-lease accounting: the wait is end-to-end latency (added
        to wall_s) but contention noise for the runtime profile (mirrored
        into queue_wait_s, which feedback subtracts — the fix for the
        ROADMAP-noted per-operator profile pollution)."""
        if lease is not None:
            m.wall_s += lease.wait_s
            m.queue_wait_s += lease.wait_s
            m.batched = m.batched or lease.batched

    def execute(self, plan) -> QueryResult:
        if not isinstance(plan, PHYSICAL_NODES):
            # logical IR (or a fluent Query): route through the rewrite
            # planner, which chains physical fragments back through this
            # executor — same selector, same profile, merged metrics.
            # This is the QUERY boundary: per-thread fault state (device
            # failure count, forced path) resets here so one query's sick
            # device never pins the next query linear.
            from .planner import plan_program

            node = plan.logical() if hasattr(plan, "logical") else plan
            self._reset_fault_state()
            try:
                return plan_program(node).run(self)
            finally:
                self._reset_fault_state()
        # Physical fragment: retry TransientErrors with exponential backoff
        # + jitter.  Fragments are pure (inputs are immutable relations; all
        # scratch state — spill manager, leases, holds — is per-attempt and
        # released on the way out), so re-running one is safe.  Planner
        # stages re-enter here per fragment, which scopes the retry to the
        # failed fragment instead of the whole multi-stage program.
        attempt = 0
        while True:
            attempt += 1
            try:
                return self._execute_physical(plan)
            except TransientError as exc:
                self._note_transient(exc)
                if attempt >= self.retry.max_attempts:
                    raise
                time.sleep(self.retry.backoff(attempt))

    def _execute_physical(self, plan) -> QueryResult:
        metrics: List[OpMetrics] = []
        decisions: List[Decision] = []

        # fused device-resident fast path for recognized fragments
        self._tls.fragment_switch = None
        if (self.fuse and self.selector.force != "linear"
                and not self._forced_linear()):
            fused = self._try_fused(plan, metrics, decisions)
            if fused is not None:
                return fused

        with self._spill_manager() as mgr:
            out = self._exec(plan, metrics, decisions, mgr)
            out = self._materialize_root(out, metrics)
        result = (QueryResult(out, None, metrics, decisions)
                  if isinstance(out, Relation)
                  else QueryResult(None, float(out), metrics, decisions))
        sw = getattr(self._tls, "fragment_switch", None)
        if sw is not None and metrics:
            # a fragment guard abandoned the fused tensor attempt before
            # this walk: stamp the abandoned wall on the root-most metric so
            # end-to-end accounting (and ServedQuery.switched) see it
            self._tls.fragment_switch = None
            pre_wall, reason = sw
            m0 = metrics[-1]
            m0.switched = True
            m0.pre_switch_wall_s = pre_wall
            m0.pre_switch_path = "tensor"
            m0.wall_s += pre_wall
            m0.decision_reason = (m0.decision_reason + "; " + reason
                                  if m0.decision_reason else reason)
        self._record_profile(metrics)
        self._record_fragment(plan, decisions, metrics)
        return result

    def _spill_manager(self):
        """Per-query spill sink: the flat disk :class:`SpillManager`, or —
        when the session configures a tier hierarchy — a
        :class:`TierManager` routing spilled partitions/runs through
        compressed host RAM and the emulated remote tier before disk,
        absorbing its byte counters into the session-lifetime ledger at
        cleanup."""
        if self.tiers is None:
            return SpillManager(self.spill_root, faults=self.faults)
        return TierManager(root=self.spill_root, config=self.tiers,
                           faults=self.faults, retry=self.retry,
                           ledger=self.tier_ledger)

    @staticmethod
    def _apply_tier_quota(mgr, grant) -> None:
        """Scope a tiered grant's per-tier spill quotas onto the per-query
        tier manager before a linear operator spills.  No-op for the flat
        SpillManager or a plain (untiered) grant."""
        setq = getattr(mgr, "set_op_quota", None)
        if setq is None:
            return
        quotas = None if grant is None else getattr(grant, "tier_quotas",
                                                    None)
        if quotas is not None:
            setq(quotas)

    # -- runtime feedback ---------------------------------------------------
    def _record_profile(self, metrics, verified_warm: bool = False) -> None:
        """Feed observed (op, path, size-bucket) → wall_s back into the
        selector's runtime profile — the loop that self-corrects the
        crossover point without recalibration.

        Tensor-path samples carry a warmup discard unless the caller proved
        the run hit warm compiled code (``verified_warm``): the per-operator
        tensor path cannot cheaply detect a first-call jit compile, and one
        compile-included wall entering a cold cell would flip the selector
        to linear and keep it there.  Linear ops never compile and always
        record."""
        prof = getattr(self.selector, "profile", None)
        if prof is None:
            return
        for m in metrics:
            # contention is load, not execution cost (admission owns load;
            # the model owns cost), so two classes of wall never enter the
            # blend: device-queue wait, and linear walls from DEGRADED
            # grants — a spill forced by a squeezed grant says nothing
            # about the operator's full-memory cost, and one multi-second
            # burst sample would latch the cell against linear long after
            # the pressure drains
            if m.grant_degraded:
                continue
            if m.switched:
                # a guard-switched operator is a HYBRID: part linear prefix,
                # part tensor completion over the reused partitions.  Its
                # wall describes neither pure path — splitting it at the
                # switch boundary still records a partial attempt against
                # cells that price FULL runs, so the sample is dropped
                # entirely (the pre-PR behavior charged the whole mixed wall
                # to the final path's cell, poisoning its estimate).
                continue
            # the abandoned pre-switch attempt's wall (preemption requeue)
            # is excluded the same way: only the finishing run's own cost
            # enters its path's cell
            prof.record(m.op, m.path, m.rows_in,
                        m.wall_s - m.queue_wait_s - m.pre_switch_wall_s,
                        warmup_discard=(m.path == "tensor"
                                        and not verified_warm))

    def _record_fragment(self, plan, decisions, metrics) -> None:
        """When the plan WAS a fusable fragment but ran on the generic walk,
        record its end-to-end wall so choose_fragment's blend sees
        linear-fragment observations too.  Only all-LINEAR walks qualify:
        a mixed walk is an observation of neither fragment path, and a pure
        per-operator tensor walk is NOT the fused program choose_fragment
        prices (it is 2-3.5x slower; recording it as ('fragment','tensor')
        would bias the blend against fusion).  The fused dispatcher records
        its own tensor-fragment observations."""
        if not self.fuse or not decisions:
            return
        if {d.path for d in decisions} != {"linear"}:
            return
        if any(m.grant_degraded for m in metrics):
            return  # squeezed-grant spill wall: load, not fragment cost
        if any(m.preempted or m.switched for m in metrics):
            # the walk did NOT run all-linear even though the decisions say
            # so: a preemption or guard switch finished part of it on the
            # tensor path, and recording that wall against the linear
            # fragment cell is exactly the cross-path pollution this guard
            # exists to stop (regression-tested)
            return
        prof = getattr(self.selector, "profile", None)
        if prof is None:
            return
        from .fused import match_fragment

        frag = match_fragment(plan)
        if frag is None:
            return
        _, build, probe = frag
        # Under a configured tier hierarchy every linear spill routed
        # through the TierManager, so a spilling walk is an observation of
        # the TIERED linear fragment — it feeds the staircase's own profile
        # cell.  Spill-free walks are identical on both variants.
        spilled = any(d.predicted_spill_bytes > 0 for d in decisions) \
            or any(m.spill.bytes_written > 0 for m in metrics)
        frag_path = ("linear_tiered"
                     if self.tiers is not None and spilled else "linear")
        prof.record("fragment", frag_path, len(build) + len(probe),
                    sum(m.wall_s for m in metrics))

    # -- fused fragment dispatch -------------------------------------------
    def _try_fused(self, plan, metrics, decisions) -> Optional[QueryResult]:
        from .fused import match_fragment, run_fused

        frag = match_fragment(plan)
        if frag is None:
            return None
        spec, build, probe = frag
        # the fragment's dominant linear intermediate is the join hash
        # table; quoting with it makes the pressure signal (grant size AND
        # expected admission wait) the same answer the join's grant
        # acquisition would get
        mem_q, dev_q, rsv = self._quotes(
            self.selector.model.hash_need_bytes(len(build)),
            lanes=self.max_shards)
        try:
            decision = self.selector.choose_fragment(
                spec, build, probe, mem_quote=mem_q, dev_quote=dev_q,
                max_shards=self.max_shards)
            if decision.path != "tensor":
                return None  # generic walk re-quotes (and re-reserves) itself
            decisions.append(decision)
            frag_guard = None
            if self.guards:
                from .guards import ExecutionGuard

                # fragment guard: observes the fused program's capacity
                # overflows (actual join fan-out vs. the optimistic bucket)
                # and can abandon the retry loop when the re-priced linear
                # fragment beats re-running at the exact bucket
                frag_guard = ExecutionGuard(
                    self.selector.model, op="fused_pipeline",
                    t_linear=max(0.0,
                                 decision.t_linear - decision.mem_wait_s),
                    t_tensor=decision.t_tensor, predicted_spill_bytes=0,
                    rows_in=len(build) + len(probe))
            t_pre = time.perf_counter()
            try:
                result, m = run_fused(spec, build, probe,
                                      decision_reason=decision.reason,
                                      broker=self.broker,
                                      shards=decision.shards,
                                      guard=frag_guard)
            except TransientError:
                # an injected/real infrastructure fault is NOT a fallback
                # case: it must reach the retry loop (and the device-failure
                # counter), not silently reroute onto the generic walk
                decisions.pop()
                raise
            except SwitchPoint as sp:
                # the fragment guard reversed the decision on observed
                # fan-out: hand the plan to the generic walk, which
                # re-quotes with its own (now wiser) decisions; the
                # abandoned wall is stamped after the walk completes
                decisions.pop()
                self._tls.fragment_switch = (time.perf_counter() - t_pre,
                                             sp.reason)
                self.broker.note_switch()
                return None
            except Exception:
                # e.g. a predicate that cannot trace (np.nonzero & friends):
                # fall back to the generic walk, which evaluates it on host
                decisions.pop()
                return None
        finally:
            if rsv is not None:
                rsv.cancel()  # fused runs on device; the memory hold lapses
        m.decision_reason = decision.reason
        metrics.append(m)
        # Feedback hygiene: a run that compiled a new program is not a
        # steady-state observation — recording its wall would poison the
        # profile and flip the very next decision back to linear.  Only
        # warm (cache-hitting) runs feed the loop.  The per-run `compiled`
        # flag, not a global counter delta: another thread's concurrent
        # compile must not make THIS warm run look cold.
        if not m.compiled:
            self._record_profile(metrics, verified_warm=True)
            prof = getattr(self.selector, "profile", None)
            if prof is not None:
                # sharded runs feed their own profile cell: the two fused
                # programs have different cost structures, and blending
                # them would drag each estimate toward the other's regime
                frag_path = "tensor_sharded" if m.devices > 1 else "tensor"
                prof.record("fragment", frag_path, len(build) + len(probe),
                            m.wall_s - m.queue_wait_s)
        if isinstance(result, Relation):
            return QueryResult(result, None, metrics, decisions)
        return QueryResult(None, float(result), metrics, decisions)

    # -- root materialization ----------------------------------------------
    def _materialize_root(self, out, metrics):
        """The single host-materialization point of a device-resident query.

        This is where the per-operator tensor path's LAZY device work
        actually executes (pending gathers + the result fetch), so it — not
        just the operator launch sites — runs under a device lease: without
        it, concurrent materializations would time-slice against each other
        and their walls would carry exactly the contention noise the
        ROADMAP flagged for profile feedback.
        """
        if isinstance(out, DeviceRelation):
            sig = ("materialize", out.num_physical_rows, out.names,
                   out.valid is None)
            with self._device_leased(sig) as lease:
                with Timer() as t:
                    rel = out.to_host()
            m = OpMetrics(
                op="materialize", path="tensor", rows_in=len(out),
                rows_out=len(rel), wall_s=t.elapsed, spill=SpillAccount(),
                host_syncs=1)
            self._stamp_lease(m, lease)
            metrics.append(m)
            return rel
        if isinstance(out, _DeviceScalar):
            # 0-d device scalar from an Aggregate over a device relation;
            # one fetch brings the value and its supporting row count
            with self._device_leased(("agg_fetch", out.fn)) as lease:
                with Timer() as t:
                    import jax
                    val, n_valid = (float(x) for x in
                                    jax.device_get((out.value, out.n_valid)))
            m = OpMetrics(
                op="materialize", path="tensor", rows_in=1, rows_out=1,
                wall_s=t.elapsed, spill=SpillAccount(), host_syncs=1)
            self._stamp_lease(m, lease)
            metrics.append(m)
            if out.fn in ("min", "max") and n_valid == 0:
                raise ValueError(
                    f"{out.fn} over an empty result has no identity")
            return val
        return out

    @staticmethod
    def _lower_for_linear(*rels):
        """Lower device relations for a linear-path operator (regime
        crossing).  Returns the host relations plus the number of
        device→host transfers performed, which the caller charges to the
        operator that demanded the lowering."""
        out = []
        syncs = 0
        for rel in rels:
            if isinstance(rel, DeviceRelation):
                rel = rel.to_host()
                syncs += 1
            out.append(rel)
        return (*out, syncs)

    @staticmethod
    def _to_device(rel):
        """Device residency for a tensor-path operator input.  Host base
        tables go through the device column cache (exact shapes), so
        repeated queries pay zero re-upload; packed layouts
        (core/codec_device) upload narrow codes and defer the decode to
        first consumption.  Returns the relation plus the PHYSICAL H2D
        bytes this call transferred and the same transfer priced at
        logical width, which the caller charges to the operator that
        demanded the transfer."""
        if isinstance(rel, DeviceRelation):
            return rel, 0, 0
        from .table_cache import get_device_layouts

        cols, uploaded, logical = get_device_layouts(rel, bucket=None)
        return DeviceRelation.from_codes(cols), uploaded, logical

    # -- node dispatch -----------------------------------------------------
    def _exec(self, node, metrics, decisions, mgr):
        if isinstance(node, Scan):
            return node.relation
        if isinstance(node, Project):
            child = self._exec(node.child, metrics, decisions, mgr)
            if not isinstance(child, (Relation, DeviceRelation)):
                raise TypeError(
                    "Project over a scalar-producing child (Aggregate) is "
                    "not a valid plan shape")
            # structural on both regimes: Relation.select slices the column
            # dict, DeviceRelation.select keeps lazy gathers pending
            return child.select(list(node.columns))
        if isinstance(node, Filter):
            child = self._exec(node.child, metrics, decisions, mgr)
            if isinstance(child, DeviceRelation):
                try:
                    import jax.numpy as jnp
                    mask = jnp.asarray(node.predicate(child), bool)
                    return child.mask_and(mask)
                except Exception:
                    # predicate needs host numpy: a real regime crossing,
                    # accounted against this operator
                    n_in = len(child)
                    with Timer() as t:
                        child = child.to_host()
                    metrics.append(OpMetrics(
                        op="filter_materialize", path="tensor",
                        rows_in=n_in, rows_out=len(child), wall_s=t.elapsed,
                        spill=SpillAccount(), host_syncs=1))
            mask = node.predicate(child)
            return child.take(np.nonzero(mask)[0])
        if isinstance(node, Join):
            build = self._exec(node.build, metrics, decisions, mgr)
            probe = self._exec(node.probe, metrics, decisions, mgr)
            mem_q, dev_q, rsv = self._quotes(
                self.selector.model.hash_need_bytes(len(build)))

            def join_tensor():
                dev_b, up_b, log_b = self._to_device(build)
                dev_p, up_p, log_p = self._to_device(probe)
                sig = ("join", dev_b.num_physical_rows,
                       dev_p.num_physical_rows, node.key)
                with self._device_leased(sig) as lease:
                    out, m = tensor_join_device(dev_b, dev_p, node.key)
                self._stamp_lease(m, lease)
                m.h2d_bytes += up_b + up_p
                m.h2d_bytes_logical += log_b + log_p
                return out, m

            try:
                decision = self._decide(self.selector.choose_join(
                    build, probe, node.key, mem_quote=mem_q, dev_quote=dev_q))
                decisions.append(decision)
                if decision.path == "tensor":
                    out, m = join_tensor()
                else:
                    hb, hp, syncs = self._lower_for_linear(build, probe)
                    pre_path = "linear_tiered" if decision.tiered else "linear"
                    t_pre = time.perf_counter()
                    try:
                        with self._granted(
                                self.selector.model.hash_need_bytes(len(hb)),
                                reservation=rsv) as (wm, grant):
                            self._apply_tier_quota(mgr, grant)
                            token = self._preempt_token(grant)
                            guard = self._guard(decision, "hash_join",
                                                len(hb) + len(hp), token)
                            try:
                                out, m = hash_join_linear(
                                    hb, hp, node.key, wm, mgr, cancel=guard)
                            finally:
                                self._drop_token(token)
                        m.host_syncs += syncs
                        self._stamp_grant(m, grant)
                    except PreemptedError:
                        # the broker cancelled this floor-degraded spill:
                        # requeue on the tensor path (the grant is already
                        # released by the _granted exit).  The abandoned
                        # attempt's wall is kept under pre_switch_* so
                        # end-to-end accounting stays honest without
                        # polluting the tensor profile cell.
                        pre_wall = time.perf_counter() - t_pre
                        out, m = join_tensor()
                        m.preempted = True
                        m.wall_s += pre_wall
                        m.pre_switch_wall_s = pre_wall
                        m.pre_switch_path = pre_path
                    except SwitchPoint as sp:
                        # the guard reversed the decision mid-spill: finish
                        # on the tensor path, reusing the already-spilled
                        # partitions (the grant is released; mgr is alive)
                        if sp.restart:
                            # fired mid-partition-pass: no reusable prefix
                            # yet — drop the partial spill files (keeping
                            # the books balanced) and re-run the whole
                            # join from the base relations, which hit the
                            # device column cache
                            spill = sp.spill if sp.spill is not None \
                                else SpillAccount()
                            for p in sp.pending:
                                if p:
                                    mgr.delete(p, spill)
                            out, m = join_tensor()
                            m.spill = spill
                            self._stamp_switch(m, sp, pre_path)
                            self.broker.note_switch()
                        else:
                            out, m = self._complete_join_switch(
                                sp, node.key, mgr, len(hb) + len(hp),
                                pre_path)
                        m.host_syncs += syncs
            finally:
                if rsv is not None:
                    rsv.cancel()  # idempotent; no-op after conversion
            if not m.switched:
                m.decision_reason = decision.reason
            metrics.append(m)
            return out
        if isinstance(node, Sort):
            child = self._exec(node.child, metrics, decisions, mgr)
            mem_q, dev_q, rsv = self._quotes(
                self.selector.model.sort_need_bytes(
                    len(child), child.row_bytes()))

            def sort_tensor():
                dev_c, up_c, log_c = self._to_device(child)
                sig = ("sort", dev_c.num_physical_rows, tuple(node.keys),
                       dev_c.valid is None)
                with self._device_leased(sig) as lease:
                    out, m = tensor_sort_device(dev_c, node.keys)
                self._stamp_lease(m, lease)
                m.h2d_bytes += up_c
                m.h2d_bytes_logical += log_c
                return out, m

            try:
                decision = self._decide(self.selector.choose_sort(
                    child, node.keys, mem_quote=mem_q, dev_quote=dev_q))
                decisions.append(decision)
                if decision.path == "tensor":
                    out, m = sort_tensor()
                else:
                    hc, syncs = self._lower_for_linear(child)
                    pre_path = "linear_tiered" if decision.tiered else "linear"
                    t_pre = time.perf_counter()
                    try:
                        with self._granted(
                                self.selector.model.sort_need_bytes(
                                    len(hc), hc.row_bytes()),
                                reservation=rsv) as (wm, grant):
                            self._apply_tier_quota(mgr, grant)
                            token = self._preempt_token(grant)
                            guard = self._guard(decision, "sort", len(hc),
                                                token)
                            try:
                                out, m = sort_linear(hc, node.keys, wm, mgr,
                                                     cancel=guard)
                            finally:
                                self._drop_token(token)
                        m.host_syncs += syncs
                        self._stamp_grant(m, grant)
                    except PreemptedError:
                        pre_wall = time.perf_counter() - t_pre
                        out, m = sort_tensor()
                        m.preempted = True
                        m.wall_s += pre_wall
                        m.pre_switch_wall_s = pre_wall
                        m.pre_switch_path = pre_path
                    except SwitchPoint as sp:
                        # sort has no cross-path partial order to reuse:
                        # drop the abandoned runs (balancing the spill
                        # books) and re-run from the base relation on device
                        spill = sp.spill if sp.spill is not None \
                            else SpillAccount()
                        for p in sp.pending:
                            if p:
                                mgr.delete(p, spill)
                        out, m = sort_tensor()
                        m.spill = spill
                        self._stamp_switch(m, sp, pre_path)
                        self.broker.note_switch()
            finally:
                if rsv is not None:
                    rsv.cancel()
            if not m.switched:
                m.decision_reason = decision.reason
            metrics.append(m)
            return out
        if isinstance(node, GroupBy):
            child = self._exec(node.child, metrics, decisions, mgr)
            from .aggregate import group_aggregate_device, group_aggregate_linear
            # GROUP BY is the third linearizing operator: the group hash
            # table is the linearized intermediate; selection mirrors sort
            # the probe uses the same unit estimate_sort's fits-check
            # compares (data bytes), not the group-table estimate the
            # grant below requests — mixing units would price a spill an
            # ungoverned session with the same work_mem would never see
            mem_q, dev_q, rsv = self._quotes(
                self.selector.model.sort_need_bytes(
                    len(child), child.row_bytes()))
            try:
                decision = self._decide(self.selector.choose_sort(
                    child, [node.key], mem_quote=mem_q, dev_quote=dev_q))
                decisions.append(decision)
                if decision.path == "tensor":
                    dev_c, up_c, log_c = self._to_device(child)
                    sig = ("group", dev_c.num_physical_rows,
                           tuple(node.values.items()), dev_c.valid is None)
                    with self._device_leased(sig) as lease:
                        out, m = group_aggregate_device(dev_c, node.key,
                                                        node.values)
                    self._stamp_lease(m, lease)
                    m.h2d_bytes += up_c
                    m.h2d_bytes_logical += log_c
                else:
                    child, syncs = self._lower_for_linear(child)
                    # grant sized by estimated DISTINCT groups (the group
                    # hash table's real footprint), via the cached key
                    # sketch — a low-cardinality aggregate over many rows
                    # must not hold a work_mem-sized slice of the shared
                    # budget it cannot use
                    from .table_cache import key_stats

                    st = key_stats(child, node.key)
                    scale = max(1, len(child) // max(1, st.sample_n))
                    n_groups = min(len(child), max(1, st.card * scale))
                    with self._granted(self.selector.model.hash_need_bytes(
                            n_groups), reservation=rsv) as (wm, grant):
                        self._apply_tier_quota(mgr, grant)
                        out, m = group_aggregate_linear(child, node.key,
                                                        node.values, wm, mgr)
                    m.host_syncs += syncs
                    self._stamp_grant(m, grant)
            finally:
                if rsv is not None:
                    rsv.cancel()
            m.decision_reason = decision.reason
            metrics.append(m)
            return out
        if isinstance(node, Aggregate):
            child = self._exec(node.child, metrics, decisions, mgr)
            if isinstance(child, DeviceRelation):
                return _device_aggregate(child, node.column, node.fn)
            col = child[node.column]
            if node.fn == "sum":
                return float(col.sum())
            if node.fn == "count":
                return float(len(col))
            if node.fn == "min":
                return float(col.min())
            if node.fn == "max":
                return float(col.max())
            raise ValueError(node.fn)
        raise TypeError(f"unknown plan node {node!r}")


@dataclasses.dataclass
class _DeviceScalar:
    """A deferred aggregate: the 0-d device value plus the valid-row count
    backing it (min/max over zero rows has no identity and must error at
    materialization, matching the host path's numpy reduction)."""
    value: object
    n_valid: object
    fn: str


def _device_aggregate(rel: DeviceRelation, column: str, fn: str) -> _DeviceScalar:
    """Masked scalar reduction on device; the root fetches the 0-d result."""
    import jax.numpy as jnp

    col = rel.col(column)
    valid = rel.valid
    is_int = jnp.issubdtype(col.dtype, jnp.integer)
    n_valid = (jnp.asarray(col.shape[0], jnp.int64) if valid is None
               else valid.sum())
    if fn == "sum":
        if valid is None:
            out = col.sum()
        else:
            out = jnp.where(valid, col, jnp.asarray(0, col.dtype)).sum()
    elif fn == "count":
        out = n_valid
    elif fn == "min":
        fill = jnp.iinfo(col.dtype).max if is_int else jnp.inf
        out = (col if valid is None else jnp.where(valid, col, fill)).min()
    elif fn == "max":
        fill = jnp.iinfo(col.dtype).min if is_int else -jnp.inf
        out = (col if valid is None else jnp.where(valid, col, fill)).max()
    else:
        raise ValueError(fn)
    return _DeviceScalar(out, n_valid, fn)
