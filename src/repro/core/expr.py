"""Typed expression language for declarative predicates and projections.

The seed front-end took opaque Python lambdas as filter predicates, which the
engine could not introspect: pushdown was impossible, projection pruning was
impossible, and compiled-program caching had to fall back to fragile bytecode
hashing (``fused._predicate_key``).  An :class:`Expr` tree is the declarative
replacement — a tiny algebra over columns:

    >>> from repro.core.expr import col
    >>> pred = (col("w") > 0) & ~col("flag").isin([2, 3])

An expression simultaneously supports every execution regime the engine has:

  * **linear / host** — calling ``pred(relation)`` evaluates with numpy and
    returns a row mask (the relational WHERE contract);
  * **fused / device** — the same call traces through jax inside a jitted
    program (operands are jnp arrays or tracers; ``isin`` dispatches on the
    operand type);
  * **planning** — :meth:`Expr.columns` names exactly the columns the
    predicate reads (filter pushdown, projection pruning) and
    :meth:`Expr.cache_token` is a canonical value-identity for compiled-
    program caching: two independently *rebuilt* but structurally equal
    expressions share one compiled program, and any change of structure,
    column, constant value, or constant *type* is a different token.

Expressions are immutable; operators build new nodes.  ``&``/``|``/``~`` are
the boolean connectives (Python's ``and``/``or`` cannot be overloaded).
"""
from __future__ import annotations

import dataclasses
import operator
from typing import Callable, Dict, FrozenSet, Tuple

import numpy as np

__all__ = ["Expr", "Col", "Lit", "BinOp", "Not", "IsIn", "CombinedPredicate",
           "col", "lit"]


_BIN_OPS: Dict[str, Callable] = {
    "+": operator.add,
    "-": operator.sub,
    "*": operator.mul,
    "/": operator.truediv,
    "//": operator.floordiv,
    "%": operator.mod,
    ">": operator.gt,
    ">=": operator.ge,
    "<": operator.lt,
    "<=": operator.le,
    "==": operator.eq,
    "!=": operator.ne,
    "&": operator.and_,
    "|": operator.or_,
}

# Literal types whose VALUES are canonical cache-key material.  Type-tagged
# in tokens because Python equates across them (1 == 1.0 == True) while the
# traced program bakes the dtype in.
_LIT_TYPES = (bool, int, float)


def _coerce(v) -> "Expr":
    if isinstance(v, Expr):
        return v
    if isinstance(v, _LIT_TYPES):
        return Lit(v)
    if isinstance(v, (np.bool_, np.integer, np.floating)):
        return Lit(v.item())
    raise TypeError(
        f"cannot use {type(v).__name__} in an expression; expected an Expr "
        f"or a bool/int/float literal")


class Expr:
    """Base expression node.  Calling an expression evaluates it against a
    column view — anything supporting ``view[name] -> array`` (a host
    ``Relation``, a ``DeviceRelation``, the fused pipeline's ``_JoinView``,
    or a plain dict of arrays)."""

    # -- evaluation --------------------------------------------------------
    def __call__(self, view):
        raise NotImplementedError

    # -- planning introspection -------------------------------------------
    def columns(self) -> FrozenSet[str]:
        """Names of every column this expression reads."""
        raise NotImplementedError

    def cache_token(self) -> Tuple:
        """Canonical, hashable value-identity of this expression.

        Stable across rebuilt-but-equal trees; distinct whenever structure,
        a column name, a constant value, or a constant type differs.
        """
        raise NotImplementedError

    def rename_columns(self, mapping: Dict[str, str]) -> "Expr":
        """A copy with column references renamed (planner pushdown uses this
        to translate join-output names like ``b_v`` to child names)."""
        raise NotImplementedError

    # -- operator algebra --------------------------------------------------
    def _bin(self, op: str, other, reflected: bool = False) -> "BinOp":
        other = _coerce(other)
        return BinOp(op, other, self) if reflected else BinOp(op, self, other)

    def __add__(self, o): return self._bin("+", o)
    def __radd__(self, o): return self._bin("+", o, True)
    def __sub__(self, o): return self._bin("-", o)
    def __rsub__(self, o): return self._bin("-", o, True)
    def __mul__(self, o): return self._bin("*", o)
    def __rmul__(self, o): return self._bin("*", o, True)
    def __truediv__(self, o): return self._bin("/", o)
    def __rtruediv__(self, o): return self._bin("/", o, True)
    def __floordiv__(self, o): return self._bin("//", o)
    def __rfloordiv__(self, o): return self._bin("//", o, True)
    def __mod__(self, o): return self._bin("%", o)
    def __rmod__(self, o): return self._bin("%", o, True)
    def __gt__(self, o): return self._bin(">", o)
    def __ge__(self, o): return self._bin(">=", o)
    def __lt__(self, o): return self._bin("<", o)
    def __le__(self, o): return self._bin("<=", o)
    def __eq__(self, o): return self._bin("==", o)  # noqa: D105
    def __ne__(self, o): return self._bin("!=", o)
    def __and__(self, o): return self._bin("&", o)
    def __rand__(self, o): return self._bin("&", o, True)
    def __or__(self, o): return self._bin("|", o)
    def __ror__(self, o): return self._bin("|", o, True)
    def __invert__(self): return Not(self)

    # __eq__ is an expression builder, so identity hashing keeps Expr usable
    # in sets/dicts; cache keys use cache_token(), never hash(expr)
    __hash__ = object.__hash__

    def __bool__(self):
        # Python rewrites `0 < col < 10` as `(0 < col) and (col < 10)` and
        # `and`/`or` truth-test their left operand — which would silently
        # DROP that operand from the predicate.  Refuse, like pandas/polars.
        raise TypeError(
            "the truth value of an Expr is ambiguous: use `&`/`|`/`~` "
            "instead of `and`/`or`/`not`, and split chained comparisons "
            "(`a < col(...) < b` → `(col(...) > a) & (col(...) < b)`)")

    def isin(self, values) -> "IsIn":
        """Membership test against a fixed set of scalar values."""
        vals = []
        for v in values:
            if isinstance(v, (np.bool_, np.integer, np.floating)):
                v = v.item()
            if not isinstance(v, _LIT_TYPES):
                raise TypeError(f"isin values must be bool/int/float, "
                                f"got {type(v).__name__}")
            vals.append(v)
        return IsIn(self, tuple(vals))

    def conjuncts(self) -> Tuple["Expr", ...]:
        """Split a top-level AND chain into its factors (pushdown unit)."""
        if isinstance(self, BinOp) and self.op == "&":
            return self.left.conjuncts() + self.right.conjuncts()
        return (self,)


@dataclasses.dataclass(frozen=True, eq=False)
class Col(Expr):
    """Reference to a named column of the view."""

    name: str

    def __call__(self, view):
        return view[self.name]

    def columns(self):
        return frozenset((self.name,))

    def cache_token(self):
        return ("col", self.name)

    def rename_columns(self, mapping):
        return Col(mapping.get(self.name, self.name))

    def __repr__(self):
        return f"col({self.name!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class Lit(Expr):
    """Scalar constant.  The token carries the concrete Python type: ``1``,
    ``1.0`` and ``True`` compare equal but trace to different programs."""

    value: object

    def __call__(self, view):
        return self.value

    def columns(self):
        return frozenset()

    def cache_token(self):
        return ("lit", type(self.value).__name__, self.value)

    def rename_columns(self, mapping):
        return self

    def __repr__(self):
        return repr(self.value)


@dataclasses.dataclass(frozen=True, eq=False)
class BinOp(Expr):
    """Binary arithmetic / comparison / boolean operator."""

    op: str
    left: Expr
    right: Expr

    def __call__(self, view):
        return _BIN_OPS[self.op](self.left(view), self.right(view))

    def columns(self):
        return self.left.columns() | self.right.columns()

    def cache_token(self):
        return ("bin", self.op, self.left.cache_token(),
                self.right.cache_token())

    def rename_columns(self, mapping):
        return BinOp(self.op, self.left.rename_columns(mapping),
                     self.right.rename_columns(mapping))

    def __repr__(self):
        return f"({self.left!r} {self.op} {self.right!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class Not(Expr):
    """Boolean/bitwise negation."""

    child: Expr

    def __call__(self, view):
        return ~self.child(view)

    def columns(self):
        return self.child.columns()

    def cache_token(self):
        return ("not", self.child.cache_token())

    def rename_columns(self, mapping):
        return Not(self.child.rename_columns(mapping))

    def __repr__(self):
        return f"~{self.child!r}"


@dataclasses.dataclass(frozen=True, eq=False)
class IsIn(Expr):
    """Membership in a fixed scalar set; dispatches numpy vs jnp by operand
    type so the same node serves the host mask path and the traced path."""

    child: Expr
    values: Tuple

    def __call__(self, view):
        arr = self.child(view)
        if isinstance(arr, np.ndarray):
            return np.isin(arr, np.asarray(self.values))
        import jax.numpy as jnp

        return jnp.isin(arr, jnp.asarray(self.values))

    def columns(self):
        return self.child.columns()

    def cache_token(self):
        return ("isin", self.child.cache_token(),
                tuple((type(v).__name__, v) for v in self.values))

    def rename_columns(self, mapping):
        return IsIn(self.child.rename_columns(mapping), self.values)

    def __repr__(self):
        return f"{self.child!r}.isin({list(self.values)!r})"


class CombinedPredicate:
    """AND of predicate parts where at least one is an opaque callable (an
    all-``Expr`` conjunction stays a single ``BinOp`` tree instead).

    The planner merges a fragment's filters into one predicate; wrapping
    mixed parts in an ad-hoc lambda would give every planned query a fresh
    code object and defeat the fused pipeline's predicate cache (one
    re-trace + one retained compiled program per ``collect()``).  This
    class keeps the parts addressable so ``fused._predicate_key`` can
    compose a stable key from the per-part keys."""

    __slots__ = ("parts",)

    def __init__(self, parts):
        self.parts = tuple(parts)

    def __call__(self, view):
        mask = self.parts[0](view)
        for p in self.parts[1:]:
            mask = mask & p(view)
        return mask

    def __repr__(self):
        return " & ".join(repr(p) if isinstance(p, Expr) else "<fn>"
                          for p in self.parts)


def col(name: str) -> Col:
    """Column reference: the entry point of the expression language."""
    return Col(name)


def lit(value) -> Lit:
    """Explicit scalar literal (operators auto-coerce plain scalars)."""
    return _coerce(value)
