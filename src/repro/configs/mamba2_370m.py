"""Mamba2-370m [arXiv:2405.21060]: pure SSM (SSD), attention-free."""
from .base import ArchConfig, register

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    vocab_size=50_280,
    pattern=(("mamba", "none"),),
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    tie_embeddings=True,
    source="arXiv:2405.21060 (state-space duality)",
)

SMOKE = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=4,
    d_model=64,
    vocab_size=512,
    pattern=(("mamba", "none"),),
    ssm_state=16,
    ssm_headdim=16,
    ssm_expand=2,
    tie_embeddings=True,
)

register(CONFIG, SMOKE)
