"""Fluent Session/Query front-end: the primary query API.

A :class:`Session` owns everything with cross-query lifetime — the
:class:`~repro.core.executor.Executor`, the
:class:`~repro.core.path_selector.PathSelector` and its
:class:`~repro.core.runtime_profile.RuntimeProfile` feedback loop, and the
registered base tables (whose device column caches and key sketches live on
the ``Relation`` instances the session keeps alive).  A :class:`Query` is an
immutable builder over the logical IR:

    >>> import numpy as np
    >>> from repro.core import Relation, Session, col
    >>> sess = Session(work_mem=1 << 20)
    >>> sess.register("orders", Relation.from_dict(
    ...     {"uid": [1, 2, 1], "w": [10, -5, 7]}))
    >>> sess.register("users", Relation.from_dict(
    ...     {"uid": [1, 2], "region": [0, 1]}))
    >>> q = (sess.table("orders")
    ...      .join(sess.table("users"), on="uid")
    ...      .filter(col("w") > 0)
    ...      .group_by("uid", {"w": "sum"}))
    >>> q.collect().relation["sum_w"].tolist()
    [17.0]

Each ``collect()`` runs the rewrite planner (filter pushdown, projection
pruning, multi-key packing, fragment chaining) and executes the resulting
stage chain through the session's executor: every fragment is priced by
``choose_fragment`` against the *rewritten* plan, observations feed the
shared runtime profile, and repeated queries hit the session-lifetime device
caches.

Join naming contract (same as the physical engine): ``a.join(b, on=...)``
keeps ``a``'s column names and serves ``b``'s non-key columns as
``b_<name>``; ``a`` is the probe side, ``b`` the build side.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Union

from .executor import Executor, QueryResult
from .expr import Expr
from .logical import (LAggregate, LFilter, LGroupBy, LJoin, LProject, LScan,
                      LSort, LogicalNode, schema)
from .memory_governor import MemoryGovernor
from .path_selector import PathSelector
from .resource_broker import ResourceBroker
from .relation import Relation
from .runtime_profile import RuntimeProfile

__all__ = ["Session", "Query"]

MB = 1 << 20


class Session:
    """Query-stream scope: executor + selector + feedback + table registry.

    A Session is safe to share across worker threads (the serving
    configuration — see :class:`repro.core.server.QueryServer`): the
    compile cache, device column cache, and runtime profile it reaches are
    all lock-guarded, and passing a :class:`~repro.core.memory_governor.
    MemoryGovernor` makes every linear operator draw its work_mem from the
    shared budget instead of the private ``work_mem`` ceiling.  Resource
    acquisition is mediated by a :class:`~repro.core.resource_broker.
    ResourceBroker` (``self.broker``): memory leases, device dispatch
    leases, and the pressure quotes that make ``auto`` queue-aware; pass an
    explicit ``broker`` to control queue pricing or share a device queue.
    """

    def __init__(self, work_mem: int = 64 * MB, policy: str = "auto",
                 selector: Optional[PathSelector] = None,
                 profile: Optional[RuntimeProfile] = None,
                 fuse: bool = True, spill_root: Optional[str] = None,
                 governor: Optional["MemoryGovernor"] = None,
                 broker: Optional["ResourceBroker"] = None,
                 faults=None, retry=None, max_shards: int = 1,
                 tiers=None, guards: bool = True):
        if broker is not None and governor is not None \
                and broker.governor is not governor:
            raise ValueError(
                "pass either governor or broker (or a broker built over "
                "that governor); conflicting governors would split the "
                "budget accounting")
        if broker is not None and governor is None:
            governor = broker.governor
        if selector is None:
            force = None if policy == "auto" else policy
            selector = PathSelector(work_mem, force=force,
                                    profile=profile or RuntimeProfile(),
                                    tiers=None if tiers is True else tiers)
        elif profile is not None and profile is not selector.profile:
            raise ValueError(
                "pass either selector or profile: an explicit selector "
                "already owns its feedback profile")
        elif policy != "auto" and selector.force != policy:
            # Executor would overwrite selector.force in place, silently
            # re-pinning every other Session sharing this selector
            raise ValueError(
                f"policy={policy!r} conflicts with the explicit selector "
                f"(force={selector.force!r}); a shared selector's policy "
                f"belongs to the selector")
        self.selector = selector
        self.profile = selector.profile
        self.governor = governor
        # ``guards`` toggles mid-query adaptive re-planning (execution-time
        # guards on costed linear operators); off is the static-decision
        # ablation the fig14 robustness map measures against
        self.executor = Executor(work_mem, policy=policy, selector=selector,
                                 spill_root=spill_root, fuse=fuse,
                                 governor=governor, broker=broker,
                                 faults=faults, retry=retry,
                                 max_shards=max_shards, tiers=tiers,
                                 guards=guards)
        # the executor normalizes tiers (True -> default TierConfig) and
        # back-fills selector.tiers; expose the resolved config + ledger
        self.tiers = self.executor.tiers
        self.tier_ledger = self.executor.tier_ledger
        # the executor resolves the broker (private one per governor, the
        # process default otherwise); the session exposes it as the single
        # handle for leases, quotes and queue stats
        self.broker = self.executor.broker
        self._tables: Dict[str, Relation] = {}

    # -- table registry ----------------------------------------------------
    def register(self, name: str, relation) -> "Session":
        """Register a base table (a Relation or a dict of columns).  The
        session keeps the instance alive, so its device column cache and key
        sketches persist across queries."""
        if not isinstance(relation, Relation):
            relation = Relation.from_dict(relation)
        self._tables[name] = relation
        return self

    def table(self, name: str) -> "Query":
        if name not in self._tables:
            raise KeyError(f"unknown table {name!r}; registered: "
                           f"{sorted(self._tables)}")
        return Query(self, LScan(self._tables[name], name))

    def from_relation(self, relation: Relation, name: str = "t") -> "Query":
        """Ad-hoc query over an unregistered relation."""
        return Query(self, LScan(relation, name))

    # -- execution ---------------------------------------------------------
    def execute(self, plan, rewrite: bool = True) -> QueryResult:
        """Run a Query, a logical tree, or a legacy physical dataclass tree
        (lowered through :func:`repro.core.logical.from_physical`)."""
        from .planner import plan_program

        node = plan.logical() if isinstance(plan, Query) else plan
        return plan_program(node, rewrite=rewrite).run(self.executor)


class Query:
    """Immutable fluent builder over the logical IR.  Every method returns a
    new Query; nothing executes until :meth:`collect`."""

    def __init__(self, session: Session, node: LogicalNode):
        self._session = session
        self._node = node

    def logical(self) -> LogicalNode:
        return self._node

    def schema(self) -> tuple:
        """Output column names this query will produce (``()`` for a scalar
        aggregate root)."""
        return schema(self._node)

    def _derive(self, node: LogicalNode) -> "Query":
        return Query(self._session, node)

    # -- operators ---------------------------------------------------------
    def filter(self, predicate) -> "Query":
        """Keep rows where ``predicate`` holds.  Prefer an
        :class:`~repro.core.expr.Expr` (``col("w") > 0``): the planner can
        push it below joins, prune around it, and cache compiled programs by
        its canonical token.  A plain callable still works but stays opaque.
        """
        if isinstance(predicate, Expr):
            missing = predicate.columns() - set(schema(self._node))
            if missing:
                raise KeyError(f"filter references unknown column(s) "
                               f"{sorted(missing)}; have {self.schema()}")
        return self._derive(LFilter(self._node, predicate))

    def select(self, *columns: str) -> "Query":
        missing = set(columns) - set(schema(self._node))
        if missing:
            raise KeyError(f"select references unknown column(s) "
                           f"{sorted(missing)}; have {self.schema()}")
        return self._derive(LProject(self._node, tuple(columns)))

    def join(self, other: Union["Query", str, Relation],
             on: Union[str, Sequence[str]]) -> "Query":
        """Equi-join: ``self`` is the probe side (keeps its column names),
        ``other`` the build side (non-key columns served as ``b_<name>``).
        ``on`` names one or more key columns present on both sides; multiple
        keys lower to a packed single-key physical join."""
        if isinstance(other, str):
            other = self._session.table(other)
        elif isinstance(other, Relation):
            other = self._session.from_relation(other)
        keys = (on,) if isinstance(on, str) else tuple(on)
        if not keys:
            raise ValueError("join needs at least one key column")
        for side, q in (("probe", self), ("build", other)):
            missing = set(keys) - set(schema(q._node))
            if missing:
                raise KeyError(f"join key(s) {sorted(missing)} missing from "
                               f"the {side} side {schema(q._node)}")
        return self._derive(LJoin(other._node, self._node, keys))

    def sort(self, *keys: str) -> "Query":
        missing = set(keys) - set(schema(self._node))
        if missing:
            raise KeyError(f"sort references unknown column(s) "
                           f"{sorted(missing)}; have {self.schema()}")
        return self._derive(LSort(self._node, tuple(keys)))

    def group_by(self, key: str, values: Dict[str, str]) -> "Query":
        cols = {key} | set(values)
        missing = cols - set(schema(self._node))
        if missing:
            raise KeyError(f"group_by references unknown column(s) "
                           f"{sorted(missing)}; have {self.schema()}")
        return self._derive(LGroupBy(self._node, key, dict(values)))

    def aggregate(self, column: str, fn: str = "sum") -> "Query":
        """Scalar reduction root: sum | count | min | max."""
        if column not in schema(self._node):
            raise KeyError(f"aggregate column {column!r} not in "
                           f"{self.schema()}")
        return self._derive(LAggregate(self._node, column, fn))

    # -- execution ---------------------------------------------------------
    def collect(self, rewrite: bool = True) -> QueryResult:
        """Plan (rewrite → chain fragments) and execute; returns the full
        :class:`~repro.core.executor.QueryResult` with per-operator metrics
        and path decisions."""
        return self._session.execute(self, rewrite=rewrite)

    def to_relation(self) -> Relation:
        res = self.collect()
        if res.relation is None:
            raise ValueError("scalar query; use .scalar()")
        return res.relation

    def scalar(self) -> float:
        res = self.collect()
        if res.scalar is None:
            raise ValueError("relation query; use .to_relation()")
        return res.scalar

    def explain(self, rewrite: bool = True) -> str:
        """The planned stage chain, post-rewrite (pushdown, pruning, packing
        and fragment boundaries are all visible here).

        One line per physical fragment, in run order::

            stage 0: join[uid](rel[100x2], rel[1000x3]) → filter((col('w') > 0))
            stage 1: join[pid](rel[50x1], #0) → sort['uid'] → agg[sum(w)]

        Notation: ``join[keys](build, probe)`` is the fragment's equi-join
        core (``(packed)`` marks a multi-key join lowered through one packed
        int64 coordinate); ``rel[NxC]`` a base-table scan of N rows × C
        columns *after projection pruning*; ``#j`` the output of stage
        ``j`` (fragment chaining); ``scan(...)`` a single-table stage.  The
        arrow chain lists the fused-fragment stages in execution order —
        ``filter(<expr>)`` (a pushed-down typed expression; opaque callables
        print ``filter(<fn>)``), ``sort[keys]``, ``project[cols]``,
        ``group_by[k]{col: fn}``, ``agg[fn(col)]``.  Each stage line is one
        ``Join→[Filter]→[Sort]→[Aggregate]`` unit priced and executed as a
        whole, so ``QueryResult.decisions`` carries (at least) one entry per
        stage — the key for interpreting fig11 runs and benchmark CSVs.
        See ``docs/query-api.md`` for the full table.
        """
        from .planner import plan_program

        return plan_program(self._node, rewrite=rewrite).explain()

    def __repr__(self) -> str:
        cols = ", ".join(self.schema()) or "<scalar>"
        return f"Query[{cols}]"
