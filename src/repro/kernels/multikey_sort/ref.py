"""Pure-jnp oracle for the bitonic tile sort."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["tile_sort_ref"]


def tile_sort_ref(keys, vals, tile: int):
    """Sort (key, val) pairs within each tile by (key, val) ascending."""
    n = keys.shape[0]
    kt = keys.reshape(n // tile, tile)
    vt = vals.reshape(n // tile, tile)
    # composite order: primary key, tie-break val — matches kernel semantics
    order = jnp.lexsort((vt, kt), axis=-1)
    return (jnp.take_along_axis(kt, order, axis=-1).reshape(n),
            jnp.take_along_axis(vt, order, axis=-1).reshape(n))
