"""Session/Query front-end, rewrite planner, and the logical-IR shim.

Covers the PR acceptance contract: a 3-table star join built with the
fluent API executes as chained fused fragments with filter pushdown,
transfers only referenced columns, and matches the legacy dataclass tree
bit-for-bit; legacy trees still execute unchanged through the lowering
shim.
"""
import numpy as np
import pytest

from repro.core import (Aggregate, Executor, Filter, GroupBy, Join, Project,
                        QueryResult, Relation, Scan, Session, Sort, col,
                        from_physical, plan_program)


def _star_tables(n_orders=20_000, n_users=500, n_parts=200, seed=0):
    """orders(uid, pid, w, fat) ⋈ users(uid, region, fat) ⋈ parts(pid,
    price, fat); the `fat` columns are never referenced by the queries."""
    rng = np.random.default_rng(seed)
    orders = Relation({
        "uid": rng.integers(0, n_users, n_orders).astype(np.int64),
        "pid": rng.integers(0, n_parts, n_orders).astype(np.int64),
        "w": rng.integers(-50, 50, n_orders).astype(np.int64),
        "fat": rng.integers(0, 9, n_orders).astype(np.int64),
    })
    users = Relation({
        "uid": np.arange(n_users, dtype=np.int64),
        "region": rng.integers(0, 4, n_users).astype(np.int64),
        "fat": rng.integers(0, 9, n_users).astype(np.int64),
    })
    parts = Relation({
        "pid": np.arange(n_parts, dtype=np.int64),
        "price": rng.integers(1, 9, n_parts).astype(np.int64),
        "fat": rng.integers(0, 9, n_parts).astype(np.int64),
    })
    return orders, users, parts


def _star_session(policy="tensor", **tables):
    sess = Session(work_mem=1 << 20, policy=policy)
    for name, rel in tables.items():
        sess.register(name, rel)
    return sess


def _star_query(sess):
    return (sess.table("orders")
            .join(sess.table("users"), on="uid")
            .join(sess.table("parts"), on="pid")
            .filter((col("w") > 0) & (col("b_region") <= 2))
            .sort("uid")
            .aggregate("w", "sum"))


def _legacy_star_plan(orders, users, parts):
    """The same query as a seed-style physical dataclass tree."""
    return Aggregate(
        Sort(Filter(Join(Scan(parts),
                         Join(Scan(users), Scan(orders), "uid"), "pid"),
                    lambda r: (r["w"] > 0) & (r["b_region"] <= 2)),
             ["uid"]), "w", "sum")


# ---------------------------------------------------------------------------
# Acceptance: chained fused fragments + pushdown + pruning + parity
# ---------------------------------------------------------------------------

def test_star_join_acceptance():
    orders, users, parts = _star_tables()
    sess = _star_session(orders=orders, users=users, parts=parts)
    q = _star_query(sess)

    # pushdown is visible in the plan: the filter runs in stage 0 (below
    # the top join), not at the root
    lines = q.explain().splitlines()
    assert len(lines) == 2
    assert "filter" in lines[0] and "filter" not in lines[1]

    res = q.collect()
    # ≥ 2 chained fused fragments
    assert [m.op for m in res.metrics] == ["fused_pipeline",
                                           "fused_pipeline"]

    # bit-for-bit vs the legacy dataclass tree, on BOTH legacy paths
    legacy = _legacy_star_plan(orders, users, parts)
    for policy in ("linear", "tensor"):
        ref = Executor(work_mem=1 << 20, policy=policy).execute(legacy)
        assert ref.scalar == res.scalar

    # projection pruning: the never-referenced fat columns stay on host.
    # An unpruned cold run of the same query over fresh (cache-cold)
    # relations pays for them; the pruned run's H2D must be smaller by at
    # least the fat columns' padded footprint.
    o2, u2, p2 = _star_tables()
    # price the never-referenced fat columns as the engine itself would
    # upload them (packed codes under compressed layouts, logical width
    # otherwise) — measured BEFORE the unpruned run so nothing is resident
    from repro.core.table_cache import pending_upload_bytes
    fat_padded = sum(
        pending_upload_bytes(r.select(["fat"]),
                             1 << int(np.ceil(np.log2(len(r)))))
        for r in (o2, u2, p2))
    assert fat_padded > 0
    res_raw = _star_query(
        _star_session(orders=o2, users=u2, parts=p2)).collect(rewrite=False)
    assert res_raw.scalar == res.scalar
    assert res.total_h2d_bytes <= res_raw.total_h2d_bytes - fat_padded


def test_star_join_warm_queries_reupload_no_base_tables():
    orders, users, parts = _star_tables(seed=3)
    sess = _star_session(orders=orders, users=users, parts=parts)
    q = _star_query(sess)
    cold = q.collect()
    warm1 = q.collect()
    warm2 = q.collect()
    assert warm1.scalar == cold.scalar == warm2.scalar
    # warm queries still upload the per-query intermediate, but no base
    # table columns: steady state is strictly cheaper and stable
    assert warm1.total_h2d_bytes < cold.total_h2d_bytes
    assert warm2.total_h2d_bytes == warm1.total_h2d_bytes
    from repro.core.table_cache import pending_upload_bytes
    referenced = {"orders": ["uid", "pid", "w"], "users": ["uid", "region"],
                  "parts": ["pid"]}
    for name, rel in (("orders", orders), ("users", users),
                      ("parts", parts)):
        # every column the query references is device-resident at its padded
        # bucket (the pruned sub-relations share these caches); columns the
        # query never reads (fat; parts.price) were never uploaded
        bucket = 1 << int(np.ceil(np.log2(len(rel))))
        assert pending_upload_bytes(rel.select(referenced[name]),
                                    bucket) == 0
        assert pending_upload_bytes(rel.select(["fat"]), bucket) > 0


@pytest.mark.parametrize("policy", ["linear", "tensor", "auto"])
def test_star_join_policies_agree(policy):
    orders, users, parts = _star_tables(seed=5, n_orders=4000)
    sess = _star_session(policy=policy, orders=orders, users=users,
                         parts=parts)
    got = _star_query(sess).collect()
    ref = Executor(work_mem=1 << 30, policy="linear").execute(
        _legacy_star_plan(orders, users, parts))
    assert got.scalar == ref.scalar


# ---------------------------------------------------------------------------
# Legacy lowering shim: dataclass trees execute unchanged through the IR
# ---------------------------------------------------------------------------

LEGACY_SHAPES = {
    "sort_join": lambda b, p: Sort(Join(Scan(b), Scan(p), "k"), ["k", "w"]),
    "agg_sort_filter_join": lambda b, p: Aggregate(
        Sort(Filter(Join(Scan(b), Scan(p), "k"), lambda r: r["w"] % 2 == 0),
             ["k", "w"]), "w", "sum"),
    "group_by_filter_join": lambda b, p: GroupBy(
        Filter(Join(Scan(b), Scan(p), "k"), lambda r: r["w"] > 0),
        "k", {"w": "sum", "b_v": "min"}),
    "project_join": lambda b, p: Project(
        Join(Scan(b), Scan(p), "k"), ["k", "b_v"]),
    "single_table_chain": lambda b, p: Sort(
        Filter(Scan(p), lambda r: r["w"] > 10), ["w"]),
}


@pytest.mark.parametrize("shape", sorted(LEGACY_SHAPES))
def test_legacy_trees_execute_through_shim(shape):
    rng = np.random.default_rng(11)
    build = Relation({"k": rng.permutation(1500).astype(np.int64),
                      "v": rng.integers(-9, 9, 1500).astype(np.int64)})
    probe = Relation({"k": rng.integers(0, 1500, 2000).astype(np.int64),
                      "w": rng.integers(-99, 99, 2000).astype(np.int64)})
    plan = LEGACY_SHAPES[shape](build, probe)
    direct = Executor(work_mem=1 << 30, policy="linear").execute(plan)

    sess = Session(work_mem=1 << 30, policy="tensor")
    via_shim = sess.execute(LEGACY_SHAPES[shape](build, probe))
    assert isinstance(via_shim, QueryResult)
    if direct.relation is None:
        assert via_shim.scalar == direct.scalar
    else:
        assert via_shim.relation.sort_canonical().equals(
            direct.relation.sort_canonical())
    # the executor itself also accepts logical IR directly
    lowered = from_physical(LEGACY_SHAPES[shape](build, probe))
    via_exec = Executor(work_mem=1 << 30, policy="linear").execute(lowered)
    if direct.relation is None:
        assert via_exec.scalar == direct.scalar
    else:
        assert via_exec.relation.sort_canonical().equals(
            direct.relation.sort_canonical())


# ---------------------------------------------------------------------------
# Multi-key joins (key packing)
# ---------------------------------------------------------------------------

def _twokey_tables(seed, n_left=3000, n_right=400, wide=False):
    """wide=True draws both key columns from sparse pools spanning ~2^40,
    so the combined range product overflows int64 range packing and the
    planner must take the per-column factorization fallback."""
    rng = np.random.default_rng(seed)
    if wide:
        pool_a = rng.integers(0, 1 << 40, 16)
        pool_b = rng.integers(-(1 << 40), 1 << 40, 8)
        a = lambda n: rng.choice(pool_a, n)
        b = lambda n: rng.choice(pool_b, n)
    else:
        a = lambda n: rng.integers(0, 20, n)
        b = lambda n: rng.integers(-10, 10, n)
    left = Relation({"a": a(n_left).astype(np.int64),
                     "b": b(n_left).astype(np.int64),
                     "w": rng.integers(0, 100, n_left).astype(np.int64)})
    right = Relation({"a": a(n_right).astype(np.int64),
                      "b": b(n_right).astype(np.int64),
                      "v": rng.integers(0, 100, n_right).astype(np.int64)})
    return left, right


def _twokey_reference(left, right):
    matches = {}
    for i, ab in enumerate(zip(right["a"].tolist(), right["b"].tolist())):
        matches.setdefault(ab, []).append(i)
    rows = [(j, i)
            for j, ab in enumerate(zip(left["a"].tolist(),
                                       left["b"].tolist()))
            for i in matches.get(ab, [])]
    return Relation({
        "a": left["a"][[j for j, _ in rows]],
        "b": left["b"][[j for j, _ in rows]],
        "w": left["w"][[j for j, _ in rows]],
        "b_v": right["v"][[i for _, i in rows]],
    }) if rows else None


@pytest.mark.parametrize("policy", ["linear", "tensor"])
@pytest.mark.parametrize("wide", [False, True],
                         ids=["range_packed", "factorized"])
def test_multikey_join_matches_reference(policy, wide):
    left, right = _twokey_tables(13, wide=wide)
    sess = Session(work_mem=1 << 30, policy=policy)
    sess.register("L", left).register("R", right)
    out = (sess.table("L").join(sess.table("R"), on=["a", "b"])
           .sort("a", "b").to_relation())
    want = _twokey_reference(left, right)
    assert want is not None
    assert set(out.names) == {"a", "b", "w", "b_v"}  # no __pack__ leak
    assert out.sort_canonical().equals(want.sort_canonical())


@pytest.mark.parametrize("wide", [False, True],
                         ids=["range_packed", "factorized"])
def test_multikey_packed_column_cached_across_queries(wide):
    """Packed key coordinates (range-compressed AND factorized) are
    content-cached on the base relations: repeated queries reuse the same
    array objects (and so their device uploads)."""
    left, right = _twokey_tables(17, wide=wide)
    sess = Session(work_mem=1 << 30, policy="tensor")
    sess.register("L", left).register("R", right)
    q = (sess.table("L").join(sess.table("R"), on=["a", "b"])
         .group_by("a", {"w": "sum"}))
    first = q.collect()
    second = q.collect()
    assert first.relation.sort_canonical().equals(
        second.relation.sort_canonical())
    assert second.total_h2d_bytes == 0  # everything resident, pack included
    # reference parity
    want = _twokey_reference(left, right)
    ref = {}
    for a, w in zip(want["a"].tolist(), want["w"].tolist()):
        ref[a] = ref.get(a, 0) + w
    got = dict(zip(first.relation["a"].tolist(),
                   first.relation["sum_w"].tolist()))
    assert got == {int(k): float(v) for k, v in ref.items()}


def test_multikey_join_reserved_pack_name_raises():
    """A user column literally named like the synthetic pack coordinate must
    refuse loudly, not be silently overwritten (regression)."""
    from repro.core.planner import PACK_COL

    left, right = _twokey_tables(61)
    tainted = Relation(dict(left.columns, **{PACK_COL: left["w"]}))
    sess = Session(work_mem=1 << 30, policy="linear")
    sess.register("L", tainted).register("R", right)
    with pytest.raises(ValueError, match="reserved"):
        sess.table("L").join(sess.table("R"), on=["a", "b"]).collect()


def test_factorized_pack_cache_is_bounded():
    """One build table factorize-joined against a stream of distinct probe
    relations must not grow its pack cache without bound (regression)."""
    left, _ = _twokey_tables(67, wide=True)
    sess = Session(work_mem=1 << 30, policy="linear")
    sess.register("L", left)
    rng = np.random.default_rng(67)
    for i in range(12):
        probe = Relation({"a": rng.choice(left["a"], 50),
                          "b": rng.choice(left["b"], 50),
                          "v": rng.integers(0, 9, 50).astype(np.int64)})
        (sess.from_relation(probe).join(sess.table("L"), on=["a", "b"])
         .aggregate("v", "count")).collect()
    entries = [k for k in left.__dict__.get("_packed_cols", {})
               if k[0] == "factorized"]
    assert 0 < len(entries) <= 8


# ---------------------------------------------------------------------------
# Rewrites: pushdown and pruning mechanics
# ---------------------------------------------------------------------------

def test_filter_pushdown_splits_conjunctions_across_stages():
    orders, users, parts = _star_tables(n_orders=2000, seed=19)
    sess = _star_session(orders=orders, users=users, parts=parts)
    q = (sess.table("orders")
         .join(sess.table("users"), on="uid")
         .join(sess.table("parts"), on="pid")
         .filter((col("w") > 0) & (col("b_price") > 3))
         .aggregate("w", "count"))
    lines = q.explain().splitlines()
    # w-conjunct sinks to stage 0 (users⋈orders); the b_price conjunct
    # references the TOP join's build side and stays at stage 1
    assert "filter" in lines[0] and "filter" in lines[1]
    res = q.collect()
    ref = Executor(work_mem=1 << 30, policy="linear").execute(
        Aggregate(Filter(Join(Scan(parts),
                              Join(Scan(users), Scan(orders), "uid"), "pid"),
                         lambda r: (r["w"] > 0) & (r["b_price"] > 3)),
                  "w", "count"))
    assert res.scalar == ref.scalar


def test_pushdown_respects_build_side_column_shadowing():
    """A conjunct mixing probe refs with a b_-name served by the TOP join's
    build side must NOT descend into the probe subtree, where the same
    b_-name is a different column (regression: wrong results when the outer
    build shadows an inner join's b_ output)."""
    rng = np.random.default_rng(59)
    n = 2000
    orders = Relation({"uid": rng.integers(0, 50, n).astype(np.int64),
                       "pid": rng.integers(0, 30, n).astype(np.int64),
                       "w": rng.integers(-9, 9, n).astype(np.int64)})
    # BOTH users and parts carry a `region` column: after the second join,
    # b_region means parts.region (build wins), not users.region
    users = Relation({"uid": np.arange(50, dtype=np.int64),
                      "region": rng.integers(0, 3, 50).astype(np.int64)})
    parts = Relation({"pid": np.arange(30, dtype=np.int64),
                      "region": rng.integers(3, 9, 30).astype(np.int64)})
    sess = _star_session(orders=orders, users=users, parts=parts)
    q = (sess.table("orders")
         .join(sess.table("users"), on="uid")
         .join(sess.table("parts"), on="pid")
         .filter((col("w") + col("b_region")) > 6)  # mixed: w + parts.region
         .aggregate("w", "count"))
    ref = Executor(work_mem=1 << 30, policy="linear").execute(
        Aggregate(Filter(Join(Scan(parts),
                              Join(Scan(users), Scan(orders), "uid"), "pid"),
                         lambda r: (r["w"] + r["b_region"]) > 6),
                  "w", "count"))
    assert q.collect().scalar == ref.scalar
    # and a pure-b_ conjunct on the shadowed name stays at the top join too
    q2 = (sess.table("orders")
          .join(sess.table("users"), on="uid")
          .join(sess.table("parts"), on="pid")
          .filter(col("b_region") >= 5)
          .aggregate("w", "count"))
    ref2 = Executor(work_mem=1 << 30, policy="linear").execute(
        Aggregate(Filter(Join(Scan(parts),
                              Join(Scan(users), Scan(orders), "uid"), "pid"),
                         lambda r: r["b_region"] >= 5), "w", "count"))
    assert q2.collect().scalar == ref2.scalar


def test_mixed_predicate_merge_keeps_compile_cache_stable():
    """A fragment whose filters mix an opaque callable with an Expr must not
    re-trace per collect(): the merged predicate's cache key composes the
    per-part keys (regression: fresh closure per plan → one new compiled
    program per query)."""
    from repro.core import pipeline_cache_clear, pipeline_cache_info

    rng = np.random.default_rng(61)
    build = Relation({"k": rng.permutation(512).astype(np.int64),
                      "v": rng.integers(0, 9, 512).astype(np.int64)})
    probe = Relation({"k": rng.integers(0, 512, 512).astype(np.int64),
                      "w": rng.integers(-9, 9, 512).astype(np.int64)})
    sess = Session(work_mem=1 << 30, policy="tensor")
    sess.register("B", build).register("P", probe)
    pipeline_cache_clear()
    results = set()
    for _ in range(3):
        q = (sess.table("P").join(sess.table("B"), on="k")
             .filter(lambda r: r["w"] > 0)      # opaque part
             .filter(col("w") < 5)              # Expr part
             .sort("k")
             .aggregate("w", "sum"))
        results.add(q.collect().scalar)
    info = pipeline_cache_info()
    assert info["misses"] == 1 and info["programs"] == 1, info
    assert len(results) == 1


def test_opaque_callable_filter_stays_put_and_correct():
    orders, users, parts = _star_tables(n_orders=2000, seed=23)
    sess = _star_session(orders=orders, users=users, parts=parts)
    q = (sess.table("orders")
         .join(sess.table("users"), on="uid")
         .filter(lambda r: r["w"] > 0)  # opaque: no pushdown, still correct
         .aggregate("w", "sum"))
    ref = Executor(work_mem=1 << 30, policy="linear").execute(
        Aggregate(Filter(Join(Scan(users), Scan(orders), "uid"),
                         lambda r: r["w"] > 0), "w", "sum"))
    assert q.collect().scalar == ref.scalar


def test_select_prunes_scans_and_projects_output():
    orders, users, _ = _star_tables(n_orders=2000, seed=29)
    sess = _star_session(orders=orders, users=users)
    out = (sess.table("orders")
           .join(sess.table("users"), on="uid")
           .select("uid", "w", "b_region")
           .sort("uid", "w")
           .to_relation())
    assert set(out.names) == {"uid", "w", "b_region"}
    ref = Executor(work_mem=1 << 30, policy="linear").execute(
        Sort(Join(Scan(users), Scan(orders), "uid"), ["uid", "w"]))
    assert out.sort_canonical().equals(
        ref.relation.select(["uid", "w", "b_region"]).sort_canonical())


def test_group_by_then_having_style_filter():
    orders, users, _ = _star_tables(n_orders=2000, seed=31)
    sess = _star_session(orders=orders, users=users)
    out = (sess.table("orders")
           .group_by("uid", {"w": "sum"})
           .filter(col("sum_w") > 100)
           .sort("uid")
           .to_relation())
    lin = Executor(work_mem=1 << 30, policy="linear").execute(
        GroupBy(Scan(orders), "uid", {"w": "sum"}))
    keep = lin.relation["sum_w"] > 100
    want = Relation({k: v[keep] for k, v in lin.relation.columns.items()})
    assert out.sort_canonical().equals(want.sort_canonical())


def test_query_validation_errors_name_the_problem():
    orders, users, _ = _star_tables(n_orders=100, seed=37)
    sess = _star_session(orders=orders, users=users)
    with pytest.raises(KeyError, match="nope"):
        sess.table("orders").filter(col("nope") > 0)
    with pytest.raises(KeyError, match="region"):
        sess.table("orders").sort("region")  # users' column, not orders'
    with pytest.raises(KeyError, match="unknown table"):
        sess.table("missing")
    with pytest.raises(KeyError, match="pid"):
        sess.table("orders").join(sess.table("users"), on="pid")


def test_session_refuses_conflicting_policy_and_shared_selector():
    """A Session given both a non-auto policy and an explicit selector must
    refuse rather than let the Executor mutate selector.force in place,
    silently re-pinning every other Session sharing it (regression)."""
    from repro.core import PathSelector, RuntimeProfile

    sel = PathSelector(1 << 20, profile=RuntimeProfile())
    Session(selector=sel)  # auto: fine, selector untouched
    with pytest.raises(ValueError, match="conflicts"):
        Session(policy="tensor", selector=sel)
    assert sel.force is None  # the shared selector was NOT mutated
    with pytest.raises(ValueError, match="either selector or profile"):
        Session(selector=sel, profile=RuntimeProfile())


def test_plan_program_rewrite_false_matches_rewrite_true():
    orders, users, parts = _star_tables(n_orders=1500, seed=41)
    sess = _star_session(orders=orders, users=users, parts=parts)
    q = _star_query(sess)
    assert q.collect(rewrite=False).scalar == q.collect().scalar
    prog = plan_program(q.logical())
    assert len(prog.stages) == 2 and prog.scalar


def test_auto_selector_handles_device_resident_fragment_inputs():
    """choose_fragment's Expr selectivity sampling must not crash (or pull
    data to the host) when a fragment's Scan holds a DeviceRelation
    (regression: probe.head() on a device relation)."""
    from repro.core import DeviceRelation

    rng = np.random.default_rng(53)
    build = Relation({"k": rng.permutation(512).astype(np.int64),
                      "v": rng.integers(0, 9, 512).astype(np.int64)})
    probe = Relation({"k": rng.integers(0, 512, 512).astype(np.int64),
                      "w": rng.integers(-9, 9, 512).astype(np.int64)})
    plan = lambda b, p: Aggregate(
        Sort(Filter(Join(Scan(b), Scan(p), "k"), col("w") > 0), ["k"]),
        "w", "sum")
    ref = Executor(work_mem=1 << 30, policy="linear").execute(
        plan(build, probe))
    got = Executor(work_mem=1 << 30, policy="auto").execute(
        plan(DeviceRelation.from_host(build),
             DeviceRelation.from_host(probe)))
    assert got.scalar == ref.scalar


# ---------------------------------------------------------------------------
# Relation.select device-cache sharing (satellite)
# ---------------------------------------------------------------------------

def test_select_subrelation_reuses_parent_device_cache():
    from repro.core.table_cache import get_device_columns

    rng = np.random.default_rng(43)
    parent = Relation({"k": rng.permutation(4096).astype(np.int64),
                       "v": rng.integers(0, 9, 4096).astype(np.int64),
                       "fat": rng.integers(0, 9, 4096).astype(np.int64)})
    # warm the parent at the padded bucket
    _, up_parent = get_device_columns(parent, bucket=4096)
    assert up_parent > 0
    # a selected sub-relation reuses the parent's uploads: zero new bytes
    sub = parent.select(["k", "v"])
    _, up_sub = get_device_columns(sub, bucket=4096)
    assert up_sub == 0
    # and uploads THROUGH a sub-relation warm the parent and later siblings
    fresh = Relation({"k": parent["k"], "v": parent["v"],
                      "fat": parent["fat"]})
    _, up1 = get_device_columns(fresh.select(["v"]), bucket=4096)
    assert up1 > 0
    _, up2 = get_device_columns(fresh.select(["v", "k"]), bucket=4096)
    assert up2 == 4096 * 8  # only k is new; v came from the sibling's upload
    # explicit invalidation reaches PRE-EXISTING shared selections, and the
    # shared dicts survive (cleared in place, not replaced): uploads after
    # the invalidation keep warming parent and siblings alike
    pre_sub = fresh.select(["v"])
    fresh.invalidate_device_cache()
    _, up3 = get_device_columns(pre_sub, bucket=4096)
    assert up3 > 0  # the old selection sees the invalidation
    _, up4 = get_device_columns(fresh, bucket=4096)
    assert up4 == 2 * 4096 * 8  # k+fat re-upload; v re-warmed via pre_sub


def test_select_subrelation_query_transfers_zero_when_parent_warm():
    rng = np.random.default_rng(47)
    build = Relation({"k": rng.permutation(2048).astype(np.int64),
                      "v": rng.integers(0, 9, 2048).astype(np.int64)})
    probe = Relation({"k": rng.integers(0, 2048, 2048).astype(np.int64),
                      "w": rng.integers(0, 9, 2048).astype(np.int64)})
    plan = lambda b, p: Aggregate(Sort(Join(Scan(b), Scan(p), "k"), ["k"]),
                                  "w", "sum")
    ex = Executor(work_mem=1 << 30, policy="tensor")
    cold = ex.execute(plan(build, probe))
    assert cold.total_h2d_bytes > 0
    # same columns through select(): fully warm (regression: re-uploaded)
    warm = ex.execute(plan(build.select(["k", "v"]), probe.select(["k", "w"])))
    assert warm.scalar == cold.scalar
    assert warm.total_h2d_bytes == 0
