"""Jit'd wrappers for the MoE dispatch kernels.

``interpret=None`` auto-selects: Pallas interpret mode off-TPU (CPU testing),
compiled mode on TPU.  ``moe_dispatch_pallas`` is the drop-in tensor-path
dispatch for repro.models.moe (same capacity/drop semantics)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import combine_pallas, dispatch_pallas

__all__ = ["dispatch", "combine", "moe_dispatch_pallas"]


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("num_experts", "capacity", "interpret"))
def dispatch(x, eidx, slot, num_experts: int, capacity: int,
             interpret=None):
    return dispatch_pallas(x, eidx.astype(jnp.int32), slot.astype(jnp.int32),
                           num_experts, capacity,
                           interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("interpret",))
def combine(buf, eidx, slot, w, interpret=None):
    return combine_pallas(buf, eidx.astype(jnp.int32), slot.astype(jnp.int32),
                          w, interpret=_auto_interpret(interpret))


def moe_dispatch_pallas(params, x_flat, topk_idx, topk_w, cfg, capacity,
                        expert_ffn, interpret=None):
    """Full MoE layer body on the kernel path: k dispatch passes + expert FFN
    + k combine passes.  Matches _dispatch_einsum semantics exactly."""
    T, d = x_flat.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    # within-expert slot positions across ALL k assignments (shared cumsum,
    # identical to the einsum/sort paths)
    flat_e = topk_idx.reshape(-1)
    onehot_e = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.cumsum(onehot_e, axis=0) - onehot_e
    slot_flat = jnp.sum(pos * onehot_e, axis=-1).reshape(T, k)

    buf = None
    for j in range(k):
        b = dispatch(x_flat, topk_idx[:, j], slot_flat[:, j], E, capacity,
                     interpret=interpret)
        buf = b if buf is None else buf + b
    out_buf = expert_ffn(params, buf, cfg)
    y = None
    for j in range(k):
        c = combine(out_buf, topk_idx[:, j], slot_flat[:, j],
                    topk_w[:, j].astype(jnp.float32), interpret=interpret)
        y = c if y is None else y + c
    return y
