"""Paper walkthrough: the regime shift T_rel(N) = O(N) + α(N, M).

Sweeps input size against a fixed 1 MB work_mem and prints both paths' wall
time, spill volume, and the predicted-vs-measured α term — the executable
version of Figs 1/6/7 and §VI.

    PYTHONPATH=src python examples/relational_paths.py [--full]
"""
import argparse

import numpy as np

from repro.core import CostModel, Relation, sort_linear, tensor_sort

MB = 1 << 20


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="run up to N=1M")
    args = ap.parse_args()
    sizes = (50_000, 200_000, 500_000) + ((1_000_000,) if args.full else ())
    work_mem = 1 * MB
    model = CostModel()
    rng = np.random.default_rng(0)

    hdr = (f"{'N':>9s} | {'linear s':>9s} {'spill MB':>9s} {'passes':>6s} "
           f"{'pred MB':>8s} | {'tensor s':>9s} {'spill':>5s}")
    print(hdr)
    print("-" * len(hdr))
    for n in sizes:
        rel = Relation({
            "k0": rng.integers(0, 64, n).astype(np.int64),
            "k1": rng.integers(0, 1 << 16, n).astype(np.int64),
            "k2": rng.integers(0, 1 << 30, n).astype(np.int64),
            "k3": rng.integers(0, 1 << 40, n).astype(np.int64),
            "p0": rng.integers(0, 1 << 40, n).astype(np.int64),
            "p1": rng.integers(0, 1 << 40, n).astype(np.int64),
        })
        keys = ["k0", "k1", "k2", "k3"]
        _, m_lin = sort_linear(rel, keys, work_mem)
        _, m_ten = tensor_sort(rel, keys)
        pred_bytes, _ = model.sort_spill_bytes(n, rel.row_bytes(), work_mem)
        print(f"{n:9d} | {m_lin.wall_s:9.3f} {m_lin.spill.temp_mb:9.1f} "
              f"{m_lin.spill.partition_passes:6d} {pred_bytes / 1e6:8.1f} | "
              f"{m_ten.wall_s:9.3f} {m_ten.spill.temp_mb:5.1f}")
    print("\nlinear path: spill grows superlinearly with the memory deficit;")
    print("tensor path: zero spill by construction — the α(N,M) term never exists.")


if __name__ == "__main__":
    main()
