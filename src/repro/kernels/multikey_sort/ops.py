"""Jit'd wrappers: tile sort + full multi-key sort (tile runs + XLA merge)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import bitonic_tile_sort_pallas

__all__ = ["tile_sort", "multikey_sort_lsd"]


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("tile", "interpret"))
def tile_sort(keys, vals, tile: int = 1024, interpret=None):
    return bitonic_tile_sort_pallas(keys.astype(jnp.int32),
                                    vals.astype(jnp.int32), tile=tile,
                                    interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("tile", "interpret"))
def multikey_sort_lsd(key_cols, tile: int = 1024, interpret=None):
    """Stable LSD multi-key sort (paper §IV.B) with the Pallas tile sorter as
    the inner stage.  key_cols: tuple of [N] int32 arrays, most-significant
    first.  Returns the permutation.

    Each LSD pass: bitonic tile runs (VMEM) + one jnp merge of the sorted
    runs (argsort over run-local ranks is XLA's efficient merge path)."""
    n = key_cols[0].shape[0]
    perm = jnp.arange(n, dtype=jnp.int32)
    for col in key_cols[::-1]:
        keyed = col[perm]
        # stage 1: VMEM tile runs, payload = current perm position (stable)
        pos = jnp.arange(n, dtype=jnp.int32)
        k_sorted, v_sorted = tile_sort(keyed, pos, tile=tile,
                                       interpret=interpret)
        # stage 2: merge runs — stable argsort over tile-sorted keys is a
        # merge of pre-sorted runs for XLA's sort
        merge = jnp.argsort(k_sorted, stable=True)
        take = v_sorted[merge]
        perm = perm[take]
    return perm
