"""Qwen2-VL-7B [arXiv:2409.12191; hf]: text backbone with M-RoPE.
The vision frontend (dynamic-resolution patch encoder) is a STUB per the
assignment: ``input_specs()`` provides token ids plus 3-stream (t, h, w)
M-RoPE position ids."""
from .base import ArchConfig, register

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    vocab_size=152_064,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18_944,
    qkv_bias=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    modality="vision_stub",
    source="arXiv:2409.12191; hf Qwen/Qwen2-VL-7B-Instruct",
)

SMOKE = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=2,
    d_model=64,
    vocab_size=512,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=192,
    qkv_bias=True,
    mrope_sections=(4, 6, 6),
    modality="vision_stub",
)

register(CONFIG, SMOKE)
