"""Jit'd wrappers: segment sum + fused aggregate join on the kernel path.

The raw Pallas kernel (:func:`segment_sum_pallas`) requires the row count to
be a multiple of its tile size; these wrappers pad arbitrary relation sizes
(segment id 0 with value 0 is sum-neutral) so the core engine can hand them
real workloads.  Value dtype is preserved (float64 works in interpret mode,
which is the CPU fallback); TPU hardware runs float32.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import segment_sum_pallas

__all__ = ["segment_sum", "join_aggregate_kernel"]


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("num_segments", "tblk", "interpret"))
def segment_sum(seg_ids, values, num_segments: int, tblk: int = 2048,
                interpret=None):
    interpret = _auto_interpret(interpret)
    n = seg_ids.shape[0]
    if n == 0:
        dt = values.dtype if values.dtype.kind == "f" else jnp.float32
        return jnp.zeros((num_segments,), dt)
    tblk = min(tblk, n)
    vals = values
    if vals.dtype == jnp.float64 and not interpret:
        vals = vals.astype(jnp.float32)  # TPU hardware path has no f64
    elif vals.dtype.kind not in "f":
        vals = vals.astype(jnp.float32)
    pad = (-n) % max(1, tblk)
    seg = seg_ids.astype(jnp.int32)
    if pad:
        seg = jnp.concatenate([seg, jnp.zeros((pad,), jnp.int32)])
        vals = jnp.concatenate([vals, jnp.zeros((pad,), vals.dtype)])
    return segment_sum_pallas(seg, vals, num_segments,
                              tblk=tblk, interpret=interpret)


@partial(jax.jit, static_argnames=("num_segments", "interpret"))
def join_aggregate_kernel(build_keys, build_vals, probe_keys, probe_vals,
                          num_segments: int, interpret=None):
    """Σ over (virtual) join pairs of b·p — join output never materialized."""
    sb = segment_sum(build_keys, build_vals, num_segments, interpret=interpret)
    sp = segment_sum(probe_keys, probe_vals, num_segments, interpret=interpret)
    cb = segment_sum(build_keys, jnp.ones_like(build_vals, jnp.float32),
                     num_segments, interpret=interpret)
    cp = segment_sum(probe_keys, jnp.ones_like(probe_vals, jnp.float32),
                     num_segments, interpret=interpret)
    return {"count": jnp.dot(cb, cp), "sum_prod": jnp.dot(sb, sp),
            "sum_add": jnp.dot(sb, cp) + jnp.dot(cb, sp)}
