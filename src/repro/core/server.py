"""Concurrent serving layer: a closed-loop query stream over one Session.

This is the repo's traffic model for the paper's headline claim.  Single-query
benchmarks (fig1–fig10) measure *throughput* per path; the phase transition
the paper actually reports — linear-path P99 going multi-second under
``work_mem`` pressure while the tensor path stays sub-second — only exists
when **concurrent queries contend for one memory pool**.  A
:class:`QueryServer` provides exactly that:

  * one :class:`~repro.core.session.Session` shared by every worker (shared
    device column cache, compiled-program cache, runtime profile — the
    serving configuration);
  * one :class:`~repro.core.memory_governor.MemoryGovernor` owning the total
    memory budget; every linear operator runs under a grant, so N concurrent
    linear queries genuinely squeeze each other into the spill regime;
  * a **closed-loop** driver: each of N workers submits its next query the
    moment the previous one completes (classic closed-loop load generation —
    offered concurrency is exactly N, no coordinated-omission artifacts from
    an open-loop arrival queue backing up).

:meth:`QueryServer.serve` returns a :class:`ServeReport` with the full
latency sample set, P50/P99, per-query spill volume and grant sizes, and the
governor's invariant counters (``over_budget_events`` must be 0).  Results
are collected per workload item so callers can assert bit-for-bit parity
against a serial run of the same queries (see ``tests/test_server.py``).

    >>> server = QueryServer({"orders": orders, "users": users},
    ...                      total_mem=64 * MB, work_mem=32 * MB)
    >>> q = server.session.table("orders").join("users", on="uid") \\
    ...           .sort("uid").aggregate("w", "sum")
    >>> report = server.serve([q], concurrency=8, queries_per_worker=4)
    >>> report.latency.p99, report.governor.over_budget_events
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence

from .executor import QueryResult
from .memory_governor import GovernorStats, MemoryGovernor
from .metrics import LatencyStats, Timer, latency_stats
from .relation import Relation
from .resource_broker import BrokerStats, DeviceQueue, ResourceBroker
from .session import Query, Session

__all__ = ["QueryServer", "ServeReport", "ServedQuery"]

MB = 1 << 20


@dataclasses.dataclass
class ServedQuery:
    """One completed query of a closed-loop run."""

    worker: int
    seq: int               # per-worker sequence number
    workload_idx: int      # which workload item this was
    wall_s: float          # end-to-end latency incl. admission wait
    temp_mb: float         # temp-file bytes this query spilled
    grant_bytes: int       # smallest grant any of its linear operators got
    paths: str             # "tensor", "linear", or "mixed"
    scalar: Optional[float]
    relation: Optional[Relation]
    mem_wait_s: float = 0.0    # total memory-admission wait across operators
    queue_wait_s: float = 0.0  # total device-lease wait across operators
    batched: bool = False      # any dispatch ran in a coalesced lease group


@dataclasses.dataclass
class ServeReport:
    """Aggregate of one :meth:`QueryServer.serve` run."""

    queries: List[ServedQuery]
    latency: LatencyStats
    wall_s: float                  # whole-run wall time
    total_temp_mb: float
    governor: GovernorStats
    concurrency: int
    # per-run broker accounting (device dispatch groups/coalescing, lease
    # waits, quote counts); EWMA/peak fields are end-of-run gauges
    broker: Optional[BrokerStats] = None

    @property
    def qps(self) -> float:
        return len(self.queries) / max(self.wall_s, 1e-9)

    @property
    def p99_over_p50(self) -> float:
        """The paper's stability metric: tail amplification of the latency
        distribution.  ~1 = predictable; >>1 = the spill-regime tail."""
        return self.latency.p99 / max(self.latency.p50, 1e-9)

    def by_workload(self, idx: int) -> List[ServedQuery]:
        return [q for q in self.queries if q.workload_idx == idx]


def _min_grant_of(result: QueryResult) -> int:
    grants = [m.grant_bytes for m in result.metrics if m.grant_bytes > 0]
    return min(grants) if grants else 0


def _paths_of(result: QueryResult) -> str:
    paths = {d.path for d in result.decisions}
    if len(paths) == 1:
        return next(iter(paths))
    return "mixed" if paths else "none"


class QueryServer:
    """Owns the serving-scope state: session + tables + resource broker.

    ``total_mem`` is the budget EVERY concurrent linear operator shares;
    ``work_mem`` is the per-operator ceiling a single grant may reach (the
    classic PostgreSQL meaning).  ``total_mem=None`` runs ungoverned —
    every query gets the full ``work_mem``, which reduces to the
    single-query semantics of the earlier PRs.

    Every server owns its :class:`~repro.core.resource_broker.
    ResourceBroker` (private device queue + the governor): leases, queue
    depth, EWMA waits and pressure quotes are all per-server state, so one
    server's load never pollutes another's pricing.  That isolation trades
    away cross-server device serialization — servers meant to run
    CONCURRENTLY in one process should share a queue (build their sessions
    over brokers constructed with the same
    :class:`~repro.core.resource_broker.DeviceQueue`).  ``grant_policy``
    selects the governor's degradation policy (``"floor"`` default,
    ``"proportional"`` for the PG hash_mem_multiplier analogue, or a
    :class:`~repro.core.memory_governor.GrantPolicy` instance);
    ``queue_aware=False`` disables the broker's wait pricing — the
    queue-blind ablation fig12 measures against (grant sizing stays
    pressure-aware; only the wait terms vanish); ``device_max_batch``
    bounds a coalesced device-dispatch group (``1`` = strict PR-4
    one-at-a-time serialization, ``None`` = unbounded coalescing).
    """

    def __init__(self, tables: Dict[str, Relation],
                 total_mem: Optional[int], work_mem: Optional[int] = None,
                 policy: Optional[str] = None,
                 min_grant: Optional[int] = None,
                 full_grant_wait_s: Optional[float] = None,
                 grant_policy=None,
                 queue_aware: Optional[bool] = None,
                 device_max_batch: Optional[int] = None,
                 session: Optional[Session] = None):
        if session is not None:
            # a prebuilt session owns its broker, governor, work_mem and
            # policy; silently dropping overrides would let a caller
            # believe it forced a configuration it never got
            conflicts = {"total_mem": total_mem, "work_mem": work_mem,
                         "policy": policy, "min_grant": min_grant,
                         "full_grant_wait_s": full_grant_wait_s,
                         "grant_policy": grant_policy,
                         "queue_aware": queue_aware,
                         "device_max_batch": device_max_batch}
            given = [k for k, v in conflicts.items() if v is not None]
            if given:
                raise ValueError(
                    f"pass either a prebuilt session or "
                    f"{'/'.join(given)}; an explicit session already owns "
                    f"its broker, governor, work_mem and policy")
        else:
            governor = (MemoryGovernor(
                total_mem,
                min_grant=1 * MB if min_grant is None else min_grant,
                full_grant_wait_s=full_grant_wait_s or 0.0,
                policy=grant_policy)
                if total_mem is not None else None)
            broker = ResourceBroker(
                governor,
                device_queue=DeviceQueue(max_group=device_max_batch),
                queue_pricing=True if queue_aware is None else queue_aware)
            session = Session(
                work_mem=32 * MB if work_mem is None else work_mem,
                policy=policy or "auto", broker=broker)
        self.session = session
        self.governor = session.governor
        self.broker = session.broker
        for name, rel in tables.items():
            self.session.register(name, rel)

    # -- single query --------------------------------------------------------
    def submit(self, query) -> QueryResult:
        """Run one query through the governed session (any :class:`Query`,
        logical tree, or legacy physical tree)."""
        return self.session.execute(query)

    # -- closed-loop stream --------------------------------------------------
    def serve(self, workload: Sequence, concurrency: int,
              queries_per_worker: int, warmup: int = 1,
              keep_relations: bool = True) -> ServeReport:
        """Drive ``concurrency`` workers in a closed loop.

        Each worker executes ``queries_per_worker`` queries back-to-back,
        cycling through ``workload`` (Query objects or logical/physical
        trees) at a per-worker offset so every item sees traffic from
        several workers.  ``warmup`` serial passes over the workload run
        first, off the clock — they converge the compile cache, the device
        column cache and the runtime profile, so the measured window
        reflects steady-state serving, not first-query compilation.

        ``keep_relations=False`` drops each relation-rooted result after
        recording its size — a long measurement run otherwise pins every
        result relation in memory until the report is dropped, making the
        harness itself the dominant memory consumer while it measures
        memory-pressure behavior.

        Worker exceptions abort the run and re-raise in the caller.
        """
        if concurrency < 1:
            raise ValueError(f"concurrency must be >= 1, got {concurrency}")
        if queries_per_worker < 1:
            raise ValueError(f"queries_per_worker must be >= 1, got "
                             f"{queries_per_worker}")
        workload = list(workload)
        if not workload:
            raise ValueError("empty workload")
        for _ in range(max(0, warmup)):
            for item in workload:
                self.submit(item)

        base_stats = (self.governor.stats() if self.governor is not None
                      else GovernorStats())
        base_broker = self.broker.stats()
        served: List[ServedQuery] = []
        errors: List[BaseException] = []
        lock = threading.Lock()

        def worker(wid: int) -> None:
            try:
                for seq in range(queries_per_worker):
                    idx = (wid + seq) % len(workload)
                    with Timer() as t:
                        res = self.submit(workload[idx])
                    rec = ServedQuery(
                        worker=wid, seq=seq, workload_idx=idx,
                        wall_s=t.elapsed, temp_mb=res.total_temp_mb,
                        grant_bytes=_min_grant_of(res),
                        paths=_paths_of(res), scalar=res.scalar,
                        relation=res.relation if keep_relations else None,
                        mem_wait_s=sum(m.mem_wait_s for m in res.metrics),
                        queue_wait_s=sum(m.queue_wait_s
                                         for m in res.metrics),
                        batched=any(m.batched for m in res.metrics))
                    with lock:
                        served.append(rec)
            except BaseException as e:  # surfaced after join, never silent
                with lock:
                    errors.append(e)

        threads = [threading.Thread(target=worker, args=(w,), daemon=True)
                   for w in range(concurrency)]
        with Timer() as run_t:
            for th in threads:
                th.start()
            for th in threads:
                th.join()
        if errors:
            raise errors[0]

        gov = (self.governor.stats() if self.governor is not None
               else GovernorStats())
        # report the governor's activity for THIS run (counters are
        # cumulative; peak and invariant counters are monotone so the
        # absolute values remain the right thing to assert on)
        gov.grants -= base_stats.grants
        gov.degraded -= base_stats.degraded
        gov.waits -= base_stats.waits
        gov.wait_s_total -= base_stats.wait_s_total
        return ServeReport(
            queries=served,
            latency=latency_stats([q.wall_s for q in served]),
            wall_s=run_t.elapsed,
            total_temp_mb=sum(q.temp_mb for q in served),
            governor=gov,
            concurrency=concurrency,
            broker=self.broker.stats().since(base_broker))
