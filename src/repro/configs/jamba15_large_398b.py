"""Jamba-1.5-Large 398B [arXiv:2403.19887; hf]: hybrid Mamba+attention 7:1
interleave, MoE 16e top-2 on every other layer (matches the 398B-total /
94B-active ratio with the assigned d_ff=24576 — DESIGN.md §8).

Deviation note: the substrate's SSM block is Mamba-2 (SSD); Jamba's original
layers are Mamba-1.  The state-size/interleave structure (and everything the
dry-run/roofline measures) is preserved; see DESIGN.md §8.
"""
from .base import ArchConfig, register

_PERIOD = (
    ("attn:global", "dense"),
    ("mamba", "moe"),
    ("mamba", "dense"),
    ("mamba", "moe"),
    ("mamba", "dense"),
    ("mamba", "moe"),
    ("mamba", "dense"),
    ("mamba", "moe"),
)

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    vocab_size=65_536,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24_576,
    pattern=_PERIOD,
    num_experts=16,
    experts_per_token=2,
    moe_d_ff=24_576,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    rope_theta=10_000.0,
    source="arXiv:2403.19887; hf ai21labs/AI21-Jamba-1.5-Large",
)

SMOKE = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=8,
    d_model=64,
    vocab_size=512,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    pattern=_PERIOD,
    capacity_factor=16.0,  # no-drop capacity for decode-equivalence smoke tests
    num_experts=4,
    experts_per_token=2,
    moe_d_ff=128,
    ssm_state=16,
    ssm_headdim=16,
    ssm_expand=2,
)

register(CONFIG, SMOKE)
