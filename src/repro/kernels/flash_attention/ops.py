"""Jit'd wrapper for the flash-attention kernel (+ jnp epilogue)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas

__all__ = ["flash_attention"]


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "cap", "q_blk",
                                   "kv_blk", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, cap=None,
                    q_blk: int = 256, kv_blk: int = 256, interpret=None):
    """q [B,Sq,H,D]; k/v [B,Sk,KH,D(v)] → [B,Sq,H,Dv] (model-layout wrapper)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    acc, m, l = flash_attention_pallas(
        qt, kt, vt, causal=causal, window=window, cap=cap,
        q_blk=q_blk, kv_blk=kv_blk, interpret=_auto_interpret(interpret))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)
