"""Yi-9B [arXiv:2403.04652; hf]: llama-arch dense GQA."""
from .base import ArchConfig, register

CONFIG = ArchConfig(
    name="yi-9b",
    family="dense",
    num_layers=48,
    d_model=4096,
    vocab_size=64_000,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11_008,
    rope_theta=10_000.0,
    source="arXiv:2403.04652; hf 01-ai/Yi-9B",
)

SMOKE = ArchConfig(
    name="yi-9b",
    family="dense",
    num_layers=2,
    d_model=64,
    vocab_size=512,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=192,
)

register(CONFIG, SMOKE)
