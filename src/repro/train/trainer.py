"""Train-step factory: loss, grad accumulation, remat, optimizer application.

``make_train_step`` builds the jit-able pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` that the
launcher lowers under the production mesh.  Microbatch gradient accumulation
is a ``lax.scan`` over a reshaped batch (keeps activation memory at
1/microbatches); remat wraps each scanned period (transformer.forward).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import cross_entropy_loss, forward
from ..models.transformer import chunked_softmax_xent, hidden_forward
from .optimizer import Optimizer

__all__ = ["TrainPolicy", "make_train_step", "make_eval_step", "default_policy"]


@dataclasses.dataclass(frozen=True)
class TrainPolicy:
    optimizer: str = "adamw"
    microbatches: int = 1
    remat: bool = True
    moe_dispatch: str = "auto"
    moe_budget_bytes: int = 2 << 30
    moe_token_chunk: int = 32_768
    remat_policy: str = "full"   # full (recompute all) | dots (save matmul outs)
    grad_accum_dtype: Any = jnp.float32
    logits_sharding: Any = None   # NamedSharding: keep [B,S,V] vocab-sharded


def default_policy(cfg: ArchConfig) -> TrainPolicy:
    """Per-arch training policy (DESIGN.md §6): Adafactor + bf16-native grads
    for the 398B hybrid so optimizer state fits v5e HBM; AdamW elsewhere."""
    if cfg.param_count() > 100e9:
        return TrainPolicy(optimizer="adafactor", microbatches=1,
                           grad_accum_dtype=jnp.bfloat16)
    return TrainPolicy(optimizer="adamw", microbatches=1)


def _loss_for_batch(params, cfg: ArchConfig, mb, policy: TrainPolicy):
    # head + CE fused per sequence chunk: full [B,S,V] logits never exist
    hidden, aux = hidden_forward(
        params, cfg, mb, remat=policy.remat, remat_policy=policy.remat_policy,
        moe_dispatch=policy.moe_dispatch, moe_budget=policy.moe_budget_bytes,
        moe_token_chunk=policy.moe_token_chunk)
    loss = chunked_softmax_xent(params, cfg, hidden, mb["labels"],
                                logits_sharding=policy.logits_sharding)
    return loss + aux


def make_train_step(cfg: ArchConfig, optimizer: Optimizer,
                    policy: Optional[TrainPolicy] = None) -> Callable:
    policy = policy or default_policy(cfg)
    n_mb = policy.microbatches

    def train_step(params, opt_state, batch):
        if n_mb == 1:
            loss, grads = jax.value_and_grad(
                lambda p: _loss_for_batch(p, cfg, batch, policy))(params)
        else:
            # microbatch m = strided rows {r·n_mb + m}: keeps the ROW axis on
            # the data shards and the scanned mb axis local to every device
            # (the naive (n_mb, B/n_mb) reshape puts whole microbatches on
            # single devices → sequential execution)
            def split(x):
                return x.reshape((x.shape[0] // n_mb, n_mb) + x.shape[1:]).swapaxes(0, 1)
            def split_positions(x):  # [3, B, S] → [n_mb, 3, B/n_mb, S]
                return x.reshape((3, x.shape[1] // n_mb, n_mb) + x.shape[2:]
                                 ).transpose(2, 0, 1, 3)
            mbs = {k: (split_positions(v) if k == "positions" else split(v))
                   for k, v in batch.items()}

            def accum(carry, mb):
                loss_acc, grads_acc = carry
                loss, grads = jax.value_and_grad(
                    lambda p: _loss_for_batch(p, cfg, mb, policy))(params)
                grads_acc = jax.tree.map(
                    lambda a, g: a + g.astype(policy.grad_accum_dtype),
                    grads_acc, grads)
                return (loss_acc + loss, grads_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, policy.grad_accum_dtype), params)
            (loss_sum, grads_sum), _ = jax.lax.scan(
                accum, (jnp.zeros((), jnp.float32), zeros), mbs)
            loss = loss_sum / n_mb
            grads = jax.tree.map(lambda g: g / n_mb, grads_sum)

        new_params, new_opt, opt_metrics = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss, **opt_metrics}
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(cfg: ArchConfig, policy: Optional[TrainPolicy] = None) -> Callable:
    policy = policy or default_policy(cfg)

    def eval_step(params, batch):
        logits, aux, _ = forward(params, cfg, batch,
                                 moe_dispatch=policy.moe_dispatch)
        return cross_entropy_loss(logits, batch["labels"]) + aux

    return eval_step
