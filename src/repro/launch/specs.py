"""ShapeDtypeStruct stand-ins for every model input and state tree.

No device allocation anywhere here — everything is ``jax.eval_shape`` /
``ShapeDtypeStruct``, which is what lets the 398B configs lower on a laptop.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..configs.shapes import ShapeSpec
from ..models import init_cache, init_model
from ..train.optimizer import Optimizer

__all__ = ["input_specs", "abstract_params", "abstract_opt_state",
           "abstract_cache"]


def input_specs(cfg: ArchConfig, shape: ShapeSpec,
                with_labels: bool) -> Dict[str, jax.ShapeDtypeStruct]:
    """Batch ShapeDtypeStructs for one (arch, shape) cell."""
    B = shape.global_batch
    S = 1 if shape.kind == "decode" else shape.seq_len
    batch: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.modality == "audio_stub":
        batch["features"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.mrope_sections:
        batch["positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
    if with_labels:
        batch["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return batch


def sharded_config(cfg: ArchConfig) -> ArchConfig:
    """Production variant: vocab padded to 256 (lcm of both mesh axes)."""
    import dataclasses
    return dataclasses.replace(cfg, vocab_pad_multiple=256)


def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: init_model(jax.random.PRNGKey(0), cfg, dtype))


def abstract_opt_state(optimizer: Optimizer, params_struct):
    return jax.eval_shape(optimizer.init, params_struct)


def abstract_cache(cfg: ArchConfig, batch_size: int, max_seq: int,
                   dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: init_cache(cfg, batch_size, max_seq, dtype))
