"""The TENSOR execution path (the paper's contribution, §III–IV), in JAX.

Dimension preservation on TPU-class hardware means *static-shape, axis-
explicit* programs instead of pointer-chasing linearized intermediates:

  * ``tensor_join`` — equi-join as **sorted coordinate alignment**: the join
    key stays an explicit coordinate axis; build rows are ordered along it
    (``argsort``), probe coordinates are aligned with ``searchsorted`` and
    match ranges expanded by segment arithmetic into a *statically sized*
    index space (capacity + validity mask).  No hash table is materialized;
    memory traffic is deterministic O(N log N) — this is what keeps the path
    out of the spill-amplification regime (§VI: T_tensor(N) ≈ O(N)).

  * ``tensor_join_aggregate`` — the strongest form of delayed materialization:
    for join-then-aggregate queries the join output is **never produced**;
    both relations are segment-reduced along the shared key axis and the
    aggregate is a contraction (einsum) over that axis.

  * ``tensor_sort`` — multi-key sort performed *step-wise along key axes*
    (stable LSD passes), exactly §IV.B: the key combination is "not
    immediately reduced to linear comparison operations but sorted
    step-by-step within the multidimensional structure".

All entry points are jit-compiled with static capacities, so the compiled
program's working set is known at compile time — the tensor path cannot
"discover" at runtime that it must spill.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Relational payloads are 64-bit (SQL bigint); the tensor path must preserve
# them exactly.  Model code elsewhere in the framework always passes explicit
# dtypes, so enabling x64 here is safe for the LM substrate.
jax.config.update("jax_enable_x64", True)

from .metrics import OpMetrics, SpillAccount, Timer
from .relation import Relation

__all__ = [
    "tensor_join",
    "tensor_join_aggregate",
    "tensor_sort",
    "join_capacity",
    "aligned_join_indices",
]


def _next_pow2(n: int) -> int:
    return 1 << max(4, int(math.ceil(math.log2(max(1, n)))))


# ---------------------------------------------------------------------------
# Join: sorted coordinate alignment
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("capacity",))
def aligned_join_indices(
    build_keys: jnp.ndarray, probe_keys: jnp.ndarray, capacity: int
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Core dimension-preserving equi-join.

    Returns ``(build_idx, probe_idx, valid, total)`` where the first two are
    ``capacity``-sized gather indices into the original relations, ``valid``
    masks real matches, and ``total`` is the exact match count (callers can
    detect capacity overflow as ``total > capacity``).
    """
    order = jnp.argsort(build_keys, stable=True)
    sorted_keys = build_keys[order]
    left = jnp.searchsorted(sorted_keys, probe_keys, side="left")
    right = jnp.searchsorted(sorted_keys, probe_keys, side="right")
    counts = right - left
    ends = jnp.cumsum(counts)
    starts = ends - counts
    total = ends[-1] if counts.shape[0] else jnp.asarray(0, counts.dtype)

    slot = jnp.arange(capacity, dtype=ends.dtype)
    # which probe row does output slot s belong to?
    probe_idx = jnp.searchsorted(ends, slot, side="right")
    probe_idx_c = jnp.minimum(probe_idx, len(probe_keys) - 1)
    offset = slot - starts[probe_idx_c]
    build_pos = left[probe_idx_c] + offset
    build_idx = order[jnp.clip(build_pos, 0, len(build_keys) - 1)]
    valid = slot < total
    return build_idx, jnp.asarray(probe_idx_c), valid, total


def join_capacity(build_keys: np.ndarray, probe_keys: np.ndarray) -> int:
    """Exact match count, computed on host (cheap O(N log N) planning step).

    This models the "expected intermediate result size" signal the paper's
    execution-time selector observes (§III.C); the static capacity handed to
    the jitted join is padded to the next power of two for compile reuse.
    """
    sk = np.sort(np.asarray(build_keys))
    left = np.searchsorted(sk, probe_keys, side="left")
    right = np.searchsorted(sk, probe_keys, side="right")
    return int((right - left).sum())


def tensor_join(
    build: Relation,
    probe: Relation,
    key: str,
    capacity: Optional[int] = None,
) -> Tuple[Relation, OpMetrics]:
    """Tensor-path equi-join producing the same schema as the linear path."""
    bk = np.asarray(build[key], dtype=np.int64)
    pk = np.asarray(probe[key], dtype=np.int64)
    if len(bk) == 0 or len(pk) == 0:
        out = {name: col[:0] for name, col in probe.columns.items()}
        out.update({f"b_{n}": c[:0] for n, c in build.columns.items() if n != key})
        return Relation(out), OpMetrics(
            op="hash_join", path="tensor", rows_in=len(build) + len(probe),
            rows_out=0, wall_s=0.0, spill=SpillAccount())
    if capacity is None:
        capacity = _next_pow2(max(1, join_capacity(bk, pk)))
    with Timer() as t:
        build_idx, probe_idx, valid, total = aligned_join_indices(
            jnp.asarray(bk), jnp.asarray(pk), capacity
        )
        jax.block_until_ready((build_idx, probe_idx, valid))
        # Late materialization: gather payload columns only now, only valid rows.
        n = int(total)
        if n > capacity:
            raise ValueError(f"capacity {capacity} < exact match count {n}")
        b_idx = np.asarray(build_idx)[:n]
        p_idx = np.asarray(probe_idx)[:n]
        out = {}
        for name, col in probe.columns.items():
            out[name] = np.asarray(col)[p_idx]
        for name, col in build.columns.items():
            if name == key:
                continue
            out[f"b_{name}"] = np.asarray(col)[b_idx]
        if not out:
            out[key] = np.asarray(probe[key])[p_idx]
        result = Relation(out)
    peak = (
        bk.nbytes * 3  # keys + order + sorted copy
        + pk.nbytes * 3  # searchsorted operands
        + capacity * 8 * 3  # index space
    )
    metrics = OpMetrics(
        op="hash_join",
        path="tensor",
        rows_in=len(build) + len(probe),
        rows_out=len(result),
        wall_s=t.elapsed,
        spill=SpillAccount(),  # structurally zero: no spill regime exists
        peak_working_set_bytes=peak,
    )
    return result, metrics


# ---------------------------------------------------------------------------
# Fused join + aggregate (join output never materialized)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("num_segments",))
def _join_aggregate(
    build_keys, build_vals, probe_keys, probe_vals, num_segments: int
):
    seg_b = jax.ops.segment_sum(build_vals, build_keys, num_segments=num_segments)
    cnt_b = jax.ops.segment_sum(
        jnp.ones_like(build_vals), build_keys, num_segments=num_segments
    )
    seg_p = jax.ops.segment_sum(probe_vals, probe_keys, num_segments=num_segments)
    cnt_p = jax.ops.segment_sum(
        jnp.ones_like(probe_vals), probe_keys, num_segments=num_segments
    )
    # SUM over join pairs of (b_val + p_val) decomposes along the key axis:
    #   sum_k [ cnt_p[k]*seg_b[k] + cnt_b[k]*seg_p[k] ]
    # and SUM of products contracts directly:  sum_k seg_b[k]*seg_p[k].
    sum_pairs = jnp.dot(cnt_b, cnt_p)
    sum_add = jnp.dot(seg_b, cnt_p) + jnp.dot(cnt_b, seg_p)
    sum_prod = jnp.dot(seg_b, seg_p)
    return sum_pairs, sum_add, sum_prod


def tensor_join_aggregate(
    build: Relation,
    probe: Relation,
    key: str,
    build_val: str,
    probe_val: str,
    key_domain: int,
) -> Tuple[dict, OpMetrics]:
    """SUM-style aggregates over the join result WITHOUT materializing it.

    Returns {count, sum_add, sum_prod} == aggregates over the (virtual) join
    of ``build ⋈ probe``: pair count, Σ(b+p), Σ(b·p).
    """
    with Timer() as t:
        pairs, s_add, s_prod = _join_aggregate(
            jnp.asarray(build[key], jnp.int32),
            jnp.asarray(build[build_val], jnp.float64)
            if build[build_val].dtype.kind == "f"
            else jnp.asarray(build[build_val], jnp.float32),
            jnp.asarray(probe[key], jnp.int32),
            jnp.asarray(probe[probe_val], jnp.float32),
            key_domain,
        )
        jax.block_until_ready((pairs, s_add, s_prod))
        out = {
            "count": float(pairs),
            "sum_add": float(s_add),
            "sum_prod": float(s_prod),
        }
    metrics = OpMetrics(
        op="join_aggregate",
        path="tensor",
        rows_in=len(build) + len(probe),
        rows_out=1,
        wall_s=t.elapsed,
        spill=SpillAccount(),
        peak_working_set_bytes=key_domain * 4 * 4 + build.nbytes() + probe.nbytes(),
    )
    return out, metrics


# ---------------------------------------------------------------------------
# Sort: step-wise multi-key (stable LSD passes over key axes)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("num_keys",))
def _multikey_perm(key_cols: Tuple[jnp.ndarray, ...], num_keys: int) -> jnp.ndarray:
    n = key_cols[0].shape[0]
    perm = jnp.arange(n)
    # least-significant key first; stability makes the composition lexicographic
    for i in range(num_keys - 1, -1, -1):
        idx = jnp.argsort(key_cols[i][perm], stable=True)
        perm = perm[idx]
    return perm


def tensor_sort(
    rel: Relation, keys: Sequence[str]
) -> Tuple[Relation, OpMetrics]:
    """Tensor-path multi-key sort: per-axis stable passes, no key packing."""
    key_cols = tuple(jnp.asarray(rel[k]) for k in keys)
    with Timer() as t:
        perm = _multikey_perm(key_cols, len(keys))
        perm = np.asarray(jax.block_until_ready(perm))
        out = rel.take(perm)
    peak = rel.nbytes() + len(rel) * 8 * 2
    metrics = OpMetrics(
        op="sort",
        path="tensor",
        rows_in=len(rel),
        rows_out=len(out),
        wall_s=t.elapsed,
        spill=SpillAccount(),
        peak_working_set_bytes=peak,
    )
    return out, metrics
