"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

THE FIRST TWO LINES must run before ANY other import (jax locks the device
count on first init): they materialize 512 host placeholder devices so
``make_production_mesh`` can build the production meshes on this CPU-only
container.  Nothing here allocates device memory — inputs, params, optimizer
state and caches are all ShapeDtypeStructs.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all                # every cell, both meshes
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k --mesh single
  ... --policy '{"microbatches": 4}'   # hillclimb overrides

Each cell's artifacts (memory_analysis, cost_analysis, per-collective bytes,
roofline terms) are written incrementally to results/dryrun/<cell>.json so an
interrupted sweep resumes where it left off.
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, applicable, get_config, list_archs
from repro.distributed.sharding import (batch_specs, cache_specs, param_specs,
                                        tree_shardings)
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (abstract_cache, abstract_opt_state,
                                abstract_params, input_specs, sharded_config)
from repro.models import decode_step, prefill
from repro.roofline.analyze import analyze_hlo, roofline_terms
from repro.roofline.model_flops import model_flops
from repro.train.optimizer import make_optimizer
from repro.train.trainer import TrainPolicy, default_policy, make_train_step

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


RESID_BUDGET = 4 << 30  # per-device budget for the scan's saved residual stream

# per-arch baseline policy tweaks where the generic heuristic undershoots
# (measured against the 16 GiB HBM budget; see EXPERIMENTS.md §Dry-run)
ARCH_POLICY = {
    "phi3.5-moe-42b-a6.6b": {"microbatches": 16},
    "qwen2-vl-7b": {"microbatches": 8},
}


def _policy_for(cfg, shape, mesh, overrides: dict) -> TrainPolicy:
    policy = default_policy(cfg)
    # the depth scan saves the residual-stream carry once per period for the
    # rematerialized backward: L_periods · B_dev · S · d · 2B.  Pick the
    # microbatch count that keeps that under RESID_BUDGET.
    from repro.distributed.sharding import dp_axes
    import numpy as np
    dp = int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))
    b_dev = max(1, shape.global_batch // dp)
    resid = cfg.num_periods * b_dev * shape.seq_len * cfg.d_model * 2
    # multi-slot periods (Jamba: 7 mamba + 1 attn) keep a whole period's
    # internals live during the rematerialized backward — scale the budget
    budget = RESID_BUDGET // max(1, cfg.period // 2)
    # MoE sort-dispatch materializes the (T·k, d) permutation in f32 (fwd +
    # cotangent) — bound the per-microbatch token count accordingly
    moe_term = (b_dev * shape.seq_len * cfg.experts_per_token * cfg.d_model * 8
                if cfg.uses_moe else 0)
    mb = 1
    while (resid / mb > budget or moe_term / mb > (2 << 30)) and mb < b_dev:
        mb *= 2
    mb = max(mb, ARCH_POLICY.get(cfg.name, {}).get("microbatches", 1))
    if mb > 1:
        policy = dataclasses.replace(policy, microbatches=min(mb, b_dev))
    if overrides:
        policy = dataclasses.replace(policy, **{
            k: v for k, v in overrides.items()
            if k in {f.name for f in dataclasses.fields(TrainPolicy)}})
    return policy


def build_cell(cfg, shape, mesh, overrides):
    """Returns (jitted_fn, arg_structs) for one cell."""
    overrides = overrides or {}
    fw_kw = {k: overrides[k]
             for k in ("q_chunk", "kv_chunk", "moe_dispatch") if k in overrides}
    fsdp = overrides.get("fsdp", True)
    from repro.distributed.sharding import dp_axes
    from jax.sharding import NamedSharding, PartitionSpec as P
    logits_sh = NamedSharding(mesh, P(dp_axes(mesh), None, "model"))
    if shape.kind == "train":
        policy = _policy_for(cfg, shape, mesh, overrides)
        policy = dataclasses.replace(policy, logits_sharding=logits_sh)
        opt = make_optimizer(policy.optimizer)
        step = make_train_step(cfg, opt, policy)
        params_s = abstract_params(cfg)
        opt_s = abstract_opt_state(opt, params_s)
        batch_s = input_specs(cfg, shape, with_labels=True)
        in_sh = (tree_shardings(mesh, param_specs(params_s, cfg, fsdp=fsdp)),
                 tree_shardings(mesh, param_specs(opt_s, cfg, fsdp=fsdp)),
                 tree_shardings(mesh, batch_specs(batch_s, mesh)))
        fn = jax.jit(step, in_shardings=in_sh, donate_argnums=(0, 1))
        return fn, (params_s, opt_s, batch_s)
    if shape.kind == "prefill":
        params_s = abstract_params(cfg)
        batch_s = input_specs(cfg, shape, with_labels=False)
        in_sh = (tree_shardings(mesh, param_specs(params_s, cfg, fsdp=fsdp)),
                 tree_shardings(mesh, batch_specs(batch_s, mesh)))
        # §Perf H2: prefill re-reads K/V once per query block — 2048-wide
        # blocks cut that traffic 8× vs the 256 default (which is sized for
        # the rematerialized training backward, not forward-only prefill)
        fw_kw.setdefault("q_chunk", 2048)
        fw_kw.setdefault("kv_chunk", 2048)
        def prefill_step(params, batch):
            return prefill(params, cfg, batch, **fw_kw)
        fn = jax.jit(prefill_step, in_shardings=in_sh)
        return fn, (params_s, batch_s)
    # decode
    params_s = abstract_params(cfg)
    cache_s = abstract_cache(cfg, shape.global_batch, shape.seq_len)
    batch_s = input_specs(cfg, shape, with_labels=False)
    in_sh = (tree_shardings(mesh, param_specs(params_s, cfg, fsdp=fsdp)),
             tree_shardings(mesh, cache_specs(cache_s, cfg, mesh)),
             tree_shardings(mesh, batch_specs(batch_s, mesh)))
    def serve_step(params, cache, batch):
        return decode_step(params, cfg, cache, batch)
    fn = jax.jit(serve_step, in_shardings=in_sh, donate_argnums=(1,))
    return fn, (params_s, cache_s, batch_s)


def _mem_dict(compiled):
    try:
        m = compiled.memory_analysis()
    except Exception as e:  # backend may not implement it
        return {"error": repr(e)}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes", "host_argument_size_in_bytes",
                 "host_output_size_in_bytes", "host_temp_size_in_bytes"):
        if hasattr(m, attr):
            out[attr] = int(getattr(m, attr))
    if not out:
        out["repr"] = str(m)
    return out


def _cost_dict(compiled):
    try:
        c = compiled.cost_analysis()
    except Exception as e:
        return {"error": repr(e)}
    if isinstance(c, (list, tuple)):
        c = c[0]
    return {k: float(v) for k, v in c.items()
            if isinstance(v, (int, float)) and not k.startswith("bytes accessed operand")}


def run_cell(arch: str, shape_name: str, mesh_kind: str, overrides=None,
             out_dir: pathlib.Path = RESULTS_DIR, tag: str = ""):
    cfg = sharded_config(get_config(arch))
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "mesh_shape": list(mesh.devices.shape),
              "overrides": overrides or {}, "status": "running"}
    n_dev = mesh.devices.size
    try:
        with mesh:
            fn, args = build_cell(cfg, shape, mesh, overrides)
            t0 = time.time()
            lowered = fn.lower(*args)
            record["lower_s"] = round(time.time() - t0, 2)
            t0 = time.time()
            compiled = lowered.compile()
            record["compile_s"] = round(time.time() - t0, 2)
        record["memory_analysis"] = _mem_dict(compiled)
        record["cost_analysis"] = _cost_dict(compiled)
        print(f"[{arch} × {shape_name} × {mesh_kind}] memory_analysis:",
              record["memory_analysis"])
        print(f"[{arch} × {shape_name} × {mesh_kind}] cost_analysis:",
              {k: v for k, v in record["cost_analysis"].items()
               if k in ("flops", "bytes accessed")})
        t0 = time.time()
        try:
            hlo = compiled.as_text()
            record["hlo_text_bytes"] = len(hlo)
            # trip-count-scaled per-device HLO walk (cost_analysis counts
            # while bodies once — useless for scan-over-depth programs)
            record["hlo_walk"] = analyze_hlo(hlo)
            del hlo
        except Exception as e:
            record["hlo_walk"] = {"error_msg": repr(e)}
        record["collective_parse_s"] = round(time.time() - t0, 2)

        walk = record.get("hlo_walk", {})
        flops_dev = walk.get("flops", 0.0)
        bytes_dev = walk.get("bytes", 0.0)
        coll_dev = walk.get("collective_bytes", 0.0)
        record["roofline"] = roofline_terms(flops_dev, bytes_dev, coll_dev)
        mf = model_flops(cfg, shape)
        record["model_flops_total"] = mf
        record["model_flops_per_device"] = mf / n_dev
        # MODEL_FLOPS / HLO_FLOPs: <1 means compiled overhead (remat,
        # dispatch waste, padding); >1 means the walker missed compute
        record["useful_flops_ratio"] = (
            (mf / n_dev) / flops_dev if flops_dev else None)
        record["status"] = "ok"
    except Exception as e:
        record["status"] = "error"
        record["error"] = repr(e)
        record["traceback"] = traceback.format_exc()[-4000:]
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = out_dir / f"{arch}__{shape_name}__{mesh_kind}{suffix}.json"
    path.write_text(json.dumps(record, indent=1))
    print(f"[{arch} × {shape_name} × {mesh_kind}] -> {record['status']} "
          f"(lower {record.get('lower_s', '-')}s, compile {record.get('compile_s', '-')}s)")
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--policy", default=None, help="JSON overrides")
    ap.add_argument("--tag", default="", help="suffix for result files")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    args = ap.parse_args()

    overrides = json.loads(args.policy) if args.policy else None
    out_dir = pathlib.Path(args.out)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mesh_kind in meshes:
                suffix = f"__{args.tag}" if args.tag else ""
                path = out_dir / f"{arch}__{shape_name}__{mesh_kind}{suffix}.json"
                if args.skip_existing and path.exists():
                    prev = json.loads(path.read_text())
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[{arch} × {shape_name} × {mesh_kind}] cached "
                              f"({prev['status']})")
                        continue
                rec = run_cell(arch, shape_name, mesh_kind, overrides,
                               out_dir, args.tag)
                if rec["status"] == "error":
                    failures += 1
                    print(rec["error"])
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")
    print("dry-run complete: all cells ok")


if __name__ == "__main__":
    main()
